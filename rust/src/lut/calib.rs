//! Design-time calibration of scaleTRIM (Sec. III-A/B).
//!
//! The paper fits `X + Y + X·Y ≈ α (X_h + Y_h)` by zero-intercept least
//! squares over the full operand space, rounds `α − 1` *down* to the nearest
//! power of two (`ΔEE`), and then averages the residual Error Values per
//! segment of `S = X_h + Y_h ∈ [0, 2)` to obtain the `M` compensation
//! constants `C_i` (Eq. 4–7, Table 7).
//!
//! ## Exact class decomposition
//!
//! Brute-forcing all pairs is O(4^n) — hopeless for 16-bit and the reason the
//! paper calls 32-bit calibration "impractical". We instead exploit that both
//! the fit and the segment means only need *per-truncation-class* statistics:
//! `t = X + Y + X·Y` and, for operands drawn independently,
//!
//! ```text
//!   Σ_{a∈u, b∈v} t(a,b) = n_v·SX_u + n_u·SX_v + SX_u·SX_v
//! ```
//!
//! where `n_u = |{a : X_h(a) = u}|` and `SX_u = Σ_{a∈u} X(a)`. One O(2^n)
//! scan per operand plus O(4^h) class pairs gives the *exact* full-space
//! calibration at any bit width — this also removes the paper's stated
//! obstacle to 32-bit calibration (see DESIGN.md).

use crate::multipliers::{leading_one, truncate_fraction};

/// Fraction bits used for the fixed-point datapath constants. The paper
/// stores each compensation value in 16 bits; we carry the whole datapath at
/// 16 fraction bits (Sec. III-B: "Each compensation value is represented
/// using 16 bits").
pub const COMP_FRAC_BITS: u32 = 16;

/// Calibrated scaleTRIM(h, M) constants for one bit-width.
#[derive(Debug, Clone)]
pub struct ScaleTrimParams {
    /// Operand bit-width.
    pub bits: u32,
    /// Truncation width.
    pub h: u32,
    /// Number of compensation segments (0 = no compensation).
    pub m: u32,
    /// Fitted slope α (Fig. 5a; ≈1.407 for 8-bit h=3).
    pub alpha: f64,
    /// `ΔEE = ⌊log2(α − 1)⌋` (Fig. 5b; −2 for 8-bit h=3).
    pub delta_ee: i32,
    /// Per-segment compensation constants C_i (empty when `m == 0`).
    pub c: Vec<f64>,
    /// C_i quantised to `COMP_FRAC_BITS` fixed point (datapath constants).
    pub c_fixed: Vec<i64>,
    /// Non-uniform segment boundaries (`m − 1` strictly-increasing
    /// thresholds on `s_int`, in units of `2^-h`): `seg_bounds[i]` is the
    /// first truncated sum belonging to segment `i + 1`. Empty means the
    /// paper's uniform split — hardware MSB indexing. Non-empty only for
    /// the quantile-calibrated `scaleTRIM-Q` family
    /// ([`CalibStrategy::Quantile`](crate::calib::CalibStrategy)).
    pub seg_bounds: Vec<u64>,
}

impl ScaleTrimParams {
    /// Validate the fixed-point datapath invariants. The linearization
    /// term is realised as `(s as i64) << (F − h + ΔEE)` with
    /// `F = COMP_FRAC_BITS`: if a calibration ever yielded
    /// `ΔEE < h − F`, the shift amount would underflow to a huge `u32`
    /// and — in release builds — silently wrap to garbage products.
    /// Assert it loudly at construction instead, for every construction
    /// path ([`calibrate`], [`paper_table7_params`],
    /// [`calibrate_analytic`](crate::lut::calibrate_analytic), the
    /// strategy backends in [`crate::calib`], and `ScaleTrim::with_params`
    /// for externally supplied constants). [`ScaleTrimParams::try_validate`]
    /// is the typed form used by the artifact-store load path.
    pub fn validate(&self) {
        if let Err(msg) = self.try_validate() {
            // lint:allow(no-panic): documented panicking form of try_validate
            panic!("{msg}");
        }
    }

    /// [`ScaleTrimParams::validate`] as a typed error — the gate every
    /// loaded artifact passes before entering the calibration cache, so a
    /// corrupt (or hostile) artifact file is a rejection message, not a
    /// wrapped shift in the datapath.
    pub fn try_validate(&self) -> Result<(), String> {
        let f = COMP_FRAC_BITS as i32;
        // Compare h in the u32 domain: an `h as i32` here would wrap for
        // h ≥ 2^31 and wave a hostile artifact through this very gate.
        if !(self.h >= 1 && self.h <= COMP_FRAC_BITS) {
            return Err(format!(
                "scaleTRIM(h={}, M={}): h must be in 1..={f} (datapath carries {f} fraction bits)",
                self.h, self.m
            ));
        }
        if f - self.h as i32 + self.delta_ee < 0 {
            return Err(format!(
                "scaleTRIM(h={}, M={}): ΔEE = {} < h − F = {} — the linearization shift \
                 (F − h + ΔEE) would underflow below zero and wrap as u32",
                self.h,
                self.m,
                self.delta_ee,
                self.h as i32 - f
            ));
        }
        if !self.alpha.is_finite() {
            return Err(format!(
                "scaleTRIM(h={}, M={}): non-finite alpha {}",
                self.h, self.m, self.alpha
            ));
        }
        let m = self.m as usize;
        if self.c.len() != m || self.c_fixed.len() != self.c.len() {
            return Err(format!(
                "scaleTRIM(h={}, M={}): {} compensation constants / {} fixed-point words \
                 (expected {m} of each)",
                self.h,
                self.m,
                self.c.len(),
                self.c_fixed.len()
            ));
        }
        if !self.seg_bounds.is_empty() {
            if m == 0 || self.seg_bounds.len() != m - 1 {
                return Err(format!(
                    "scaleTRIM(h={}, M={}): {} segment boundaries (expected {} or none)",
                    self.h,
                    self.m,
                    self.seg_bounds.len(),
                    m.saturating_sub(1)
                ));
            }
            if self.seg_bounds.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!(
                    "scaleTRIM(h={}, M={}): segment boundaries not strictly increasing: {:?}",
                    self.h, self.m, self.seg_bounds
                ));
            }
        }
        Ok(())
    }

    /// Segment index for a truncated sum `s_int` in units of `2^-h`.
    /// Uniform split (empty `seg_bounds`): the hardware's top ⌈log2 M⌉
    /// bits of `X_h + Y_h`. Quantile split: the number of boundaries at or
    /// below `s_int` (hardware: `M − 1` parallel threshold comparators).
    #[inline]
    pub fn segment(&self, s_int: u64) -> usize {
        debug_assert!(self.m > 0);
        segment_of(s_int, self.m, self.h, &self.seg_bounds)
    }
}

/// The one segment-index mapping shared by the datapath
/// ([`ScaleTrimParams::segment`], the piecewise multiplier) and the
/// calibration-time averaging (`calib::strategy`): calibration must
/// aggregate residuals over exactly the segments the hardware will select,
/// so this function is deliberately the only copy of the formula.
#[inline]
pub(crate) fn segment_of(s_int: u64, m: u32, h: u32, bounds: &[u64]) -> usize {
    if bounds.is_empty() {
        // s = s_int / 2^h ∈ [0, 2); segment = floor(s · M / 2).
        // s_int < 2^(h+1) ≤ 2^13 and M ≤ PARAM_MAX = 2^6, so u64 suffices.
        debug_assert!(h + 1 < u64::BITS, "segment index shift exceeds the u64 range");
        let idx = (s_int * m as u64) >> (h + 1);
        (idx as usize).min(m as usize - 1)
    } else {
        // Bounds are validated strictly increasing: binary search gives
        // the same "number of boundaries at or below s" in O(log M).
        bounds.partition_point(|&b| b <= s_int)
    }
}

/// Per-truncation-class operand statistics for one bit-width/h: class counts
/// and fraction sums, computed in a single O(2^bits) scan.
#[derive(Debug, Clone)]
pub struct OperandClasses {
    /// `n_u`: number of operands whose truncated fraction is `u`.
    pub count: Vec<u64>,
    /// `SX_u`: sum of exact fractions `X` over that class.
    pub sum_x: Vec<f64>,
    /// Truncation width used.
    pub h: u32,
}

impl OperandClasses {
    /// Scan all non-zero operands of the given width.
    pub fn scan(bits: u32, h: u32) -> Self {
        debug_assert!(h <= bits && bits < u64::BITS, "scan width exceeds the u64 range");
        let classes = 1usize << h;
        let mut count = vec![0u64; classes];
        let mut sum_x = vec![0f64; classes];
        for a in 1u64..(1u64 << bits) {
            let n = leading_one(a);
            debug_assert!(n < bits, "leading-one position exceeds the scan width");
            let x = (a as f64) / (1u64 << n) as f64 - 1.0;
            let u = truncate_fraction(a, n, h) as usize;
            count[u] += 1;
            sum_x[u] += x;
        }
        Self { count, sum_x, h }
    }
}

/// Run the full calibration for `scaleTRIM(h, M)` at the given width.
///
/// `m == 0` produces linearization-only constants (the paper's ST(h,0)
/// rows). The fit itself — the zero-intercept α regression (Σ t·s / Σ s²
/// over all class pairs), the ΔEE power-of-two rounding (Fig. 5b) and the
/// per-segment residual averaging — is the calibration plane's single
/// shared implementation ([`crate::calib`]); this entry point contributes
/// the *exhaustive-scan* class statistics.
pub fn calibrate(bits: u32, h: u32, m: u32) -> ScaleTrimParams {
    assert!(h >= 1 && h <= 12, "h out of range");
    assert!(m == 0 || m.is_power_of_two(), "M must be 0 or a power of two");
    let cls = OperandClasses::scan(bits, h);
    let count: Vec<f64> = cls.count.iter().map(|&c| c as f64).collect();
    crate::calib::fit_uniform(bits, h, m, &count, &cls.sum_x)
}

/// The compensation constants the paper *publishes* in Table 7 (8-bit,
/// h ∈ {3..6}, M ∈ {4, 8}), with ΔEE = −2 and α as Fig. 5 reports.
///
/// Our own full-space calibration ([`calibrate`]) reproduces the paper's
/// *reported MRED* more closely than these printed constants do (e.g.
/// ST(3,4): ours 3.734% vs paper 3.73%; Table-7 constants give 4.01%) —
/// see EXPERIMENTS.md. The printed constants are kept for exact replays of
/// the paper's worked example (Fig. 7) and Table 7 itself.
pub fn paper_table7_params(h: u32, m: u32) -> Option<ScaleTrimParams> {
    let c: &[f64] = match (h, m) {
        (3, 4) => &[0.053, 0.050, 0.234, 0.468],
        (3, 8) => &[0.073, 0.039, 0.032, 0.066, 0.182, 0.317, 0.468, 0.410],
        (4, 4) => &[-0.015, -0.035, 0.114, 0.354],
        (4, 8) => &[0.008, -0.028, -0.042, -0.030, 0.063, 0.190, 0.336, 0.467],
        (5, 4) => &[-0.046, -0.073, 0.058, 0.301],
        (5, 8) => &[-0.020, -0.058, -0.076, -0.071, 0.008, 0.132, 0.274, 0.412],
        (6, 4) => &[-0.059, -0.089, 0.035, 0.277],
        (6, 8) => &[-0.032, -0.070, -0.090, -0.088, -0.016, 0.106, 0.248, 0.387],
        _ => return None,
    };
    let alpha = match h {
        3 => 1.407,
        4 => 1.331,
        5 => 1.298,
        6 => 1.284,
        _ => unreachable!(),
    };
    let q = (1u64 << COMP_FRAC_BITS) as f64;
    let params = ScaleTrimParams {
        bits: 8,
        h,
        m,
        alpha,
        delta_ee: -2,
        c: c.to_vec(),
        c_fixed: c.iter().map(|&x| (x * q).round() as i64).collect(),
        seg_bounds: Vec::new(),
    };
    params.validate();
    Some(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 5a: 8-bit, h=3 → α ≈ 1.407.
    #[test]
    fn alpha_matches_paper_h3() {
        let p = calibrate(8, 3, 0);
        assert!(
            (p.alpha - 1.407).abs() < 0.02,
            "alpha {} != paper 1.407",
            p.alpha
        );
        assert_eq!(p.delta_ee, -2, "ΔEE should be -2 (Fig. 5b)");
    }

    /// Table 7, h=3 M=4 column: C ≈ [0.053, 0.050, 0.234, 0.468]. Our
    /// full-space calibration lands close but not identical (the paper's
    /// printed constants are *not* the ones that reproduce its reported
    /// MRED — see EXPERIMENTS.md); shape and sign structure must agree.
    #[test]
    fn compensation_close_to_table7_h3_m4() {
        let p = calibrate(8, 3, 4);
        let paper = [0.053, 0.050, 0.234, 0.468];
        for (i, (&ours, &theirs)) in p.c.iter().zip(paper.iter()).enumerate() {
            assert!(
                (ours - theirs).abs() < 0.08,
                "C[{i}] = {ours:.3} vs paper {theirs}"
            );
        }
        // Monotone increase from segment 1 upward, as in the paper.
        assert!(p.c[1] < p.c[2] && p.c[2] < p.c[3]);
    }

    #[test]
    fn paper_table7_constants_available() {
        for h in 3..=6 {
            for m in [4, 8] {
                let p = paper_table7_params(h, m).unwrap();
                assert_eq!(p.c.len(), m as usize);
                assert_eq!(p.delta_ee, -2);
            }
        }
        assert!(paper_table7_params(7, 4).is_none());
    }

    /// Brute-force cross-check of the class decomposition at a small width.
    #[test]
    fn class_decomposition_matches_bruteforce() {
        let bits = 6;
        let h = 2;
        // brute force α
        let mut sum_ts = 0f64;
        let mut sum_ss = 0f64;
        for a in 1u64..(1 << bits) {
            for b in 1u64..(1 << bits) {
                let na = leading_one(a);
                let nb = leading_one(b);
                let x = a as f64 / (1u64 << na) as f64 - 1.0;
                let y = b as f64 / (1u64 << nb) as f64 - 1.0;
                let s = (truncate_fraction(a, na, h) + truncate_fraction(b, nb, h)) as f64
                    / (1u64 << h) as f64;
                let t = x + y + x * y;
                sum_ts += t * s;
                sum_ss += s * s;
            }
        }
        let alpha_bf = sum_ts / sum_ss;
        let p = calibrate(bits, h, 0);
        assert!(
            (p.alpha - alpha_bf).abs() < 1e-9,
            "decomposed {} vs brute {}",
            p.alpha,
            alpha_bf
        );
    }

    #[test]
    fn segment_indexing_covers_range() {
        let p = calibrate(8, 3, 4);
        // S ∈ [0,2) in units of 2^-3: s_int ∈ [0, 14]
        assert_eq!(p.segment(0), 0);
        assert_eq!(p.segment(3), 0); // s = 0.375
        assert_eq!(p.segment(4), 1); // s = 0.5
        assert_eq!(p.segment(6), 1); // s = 0.75 -> segment 1 (Fig. 7!)
        assert_eq!(p.segment(8), 2); // s = 1.0
        assert_eq!(p.segment(14), 3); // s = 1.75
    }

    #[test]
    fn m0_has_no_lut() {
        let p = calibrate(8, 4, 0);
        assert!(p.c.is_empty() && p.c_fixed.is_empty());
    }

    #[test]
    fn alpha_in_documented_range_for_all_h() {
        // Paper: "the range of α is between 1 and 2" (h ≥ 2; a 1-bit
        // truncation is outside the paper's evaluated set and fits α > 2).
        for h in 2..=8 {
            let p = calibrate(8, h, 0);
            assert!(
                p.alpha > 1.0 && p.alpha < 2.0,
                "h={h}: alpha {} outside (1,2)",
                p.alpha
            );
            assert!(p.delta_ee < 0);
        }
    }

    /// The linearization-shift underflow guard: ΔEE below `h − F` must be
    /// rejected at construction, not wrap at multiply time.
    #[test]
    #[should_panic(expected = "linearization shift")]
    fn validate_rejects_underflowing_delta_ee() {
        let p = ScaleTrimParams {
            bits: 8,
            h: 3,
            m: 0,
            alpha: 1.0 + (-14f64).exp2(),
            delta_ee: -14, // F − h + ΔEE = 16 − 3 − 14 = −1
            c: Vec::new(),
            c_fixed: Vec::new(),
            seg_bounds: Vec::new(),
        };
        p.validate();
    }

    #[test]
    fn validate_accepts_boundary_shift() {
        // F − h + ΔEE = 0 is legal (a 1× shift — no headroom, no wrap).
        let p = ScaleTrimParams {
            bits: 8,
            h: 3,
            m: 0,
            alpha: 1.0 + (-13f64).exp2(),
            delta_ee: -13,
            c: Vec::new(),
            c_fixed: Vec::new(),
            seg_bounds: Vec::new(),
        };
        p.validate();
    }

    #[test]
    fn try_validate_rejects_malformed_constants() {
        let mut p = calibrate(8, 3, 4);
        assert!(p.try_validate().is_ok());
        // Wrong LUT length.
        p.c_fixed.pop();
        assert!(p.try_validate().is_err());
        // Malformed quantile boundaries.
        let mut q = calibrate(8, 3, 4);
        q.seg_bounds = vec![4, 4, 9]; // not strictly increasing
        assert!(q.try_validate().is_err());
        q.seg_bounds = vec![4, 8]; // wrong count for M=4
        assert!(q.try_validate().is_err());
        q.seg_bounds = vec![3, 6, 9];
        assert!(q.try_validate().is_ok());
    }

    #[test]
    fn quantile_boundaries_drive_segment_lookup() {
        let mut p = calibrate(8, 3, 4);
        p.seg_bounds = vec![4, 8, 12];
        assert_eq!(p.segment(0), 0);
        assert_eq!(p.segment(3), 0);
        assert_eq!(p.segment(4), 1);
        assert_eq!(p.segment(11), 2);
        assert_eq!(p.segment(12), 3);
        assert_eq!(p.segment(14), 3);
    }
}
