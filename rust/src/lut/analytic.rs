//! Closed-form calibration — lifting the paper's 32-bit limitation.
//!
//! Sec. IV-C / VI: *"extending this investigation to 32-bit operands would
//! provide additional insight, [but] the preprocessing required to compute
//! the piecewise compensation values demands significant time and memory
//! resources, making such an evaluation impractical."*
//!
//! It is practical. The per-class operand statistics that drive the whole
//! calibration (`n_u`, `ΣX_u` — see `calib.rs`) have closed forms. For an
//! operand width `N`, leading-one position `n` and truncated class `u`:
//!
//! - `n ≥ h`: the class contains `2^(n-h)` operands `v = 2^n + u·2^(n-h) + r`,
//!   `r ∈ [0, 2^(n-h))`, each with `X = (u·2^(n-h) + r) / 2^n`, so
//!   `ΣX = 2^(n-h)·u/2^h + (2^(n-h)-1)·2^(n-h)/(2·2^n)`.
//! - `n < h`: classes are the zero-padded fractions `u = frac · 2^(h-n)`,
//!   one operand each, `X = frac / 2^n`.
//!
//! Summing over `n ∈ [0, N)` gives the exact full-space statistics in
//! `O(N · 2^h)` time and `O(2^h)` memory — a 32-bit calibration takes
//! microseconds instead of the paper's "impractical" `O(4^N)` pair scan.

use super::calib::ScaleTrimParams;

/// Exact per-class statistics computed in closed form (no operand scan).
pub fn analytic_classes(bits: u32, h: u32) -> (Vec<f64>, Vec<f64>) {
    debug_assert!(h < bits && bits < u64::BITS, "class width exceeds the operand width");
    let classes = 1usize << h;
    let mut count = vec![0f64; classes];
    let mut sum_x = vec![0f64; classes];
    for n in 0..bits {
        debug_assert!(n < u64::BITS, "leading-one position exceeds the u64 range");
        if n >= h {
            let block = (1u64 << (n - h)) as f64; // operands per class
            let pow_n = (1u64 << n) as f64;
            // ΣX over the block: block·u/2^h + (block−1)·block / (2·2^n)
            for (u, (cnt, sx)) in count.iter_mut().zip(sum_x.iter_mut()).enumerate() {
                *cnt += block;
                *sx += block * u as f64 / classes as f64 + (block - 1.0) * block / (2.0 * pow_n);
            }
        } else {
            // n < h: 2^n operands, each its own zero-padded class.
            let pow_n = (1u64 << n) as f64;
            for frac in 0..(1u64 << n) {
                let u = (frac << (h - n)) as usize;
                count[u] += 1.0;
                sum_x[u] += frac as f64 / pow_n;
            }
        }
    }
    (count, sum_x)
}

/// Full closed-form calibration: identical math to [`super::calibrate`]
/// but with analytic class statistics — valid for any width (8…64). The
/// fit and averaging are the calibration plane's shared implementation
/// ([`crate::calib`]); only the statistics producer differs.
pub fn calibrate_analytic(bits: u32, h: u32, m: u32) -> ScaleTrimParams {
    assert!(h >= 2 && h <= 12 && bits >= 4 && bits <= 63);
    assert!(m == 0 || m.is_power_of_two());
    let (count, sum_x) = analytic_classes(bits, h);
    crate::calib::fit_uniform(bits, h, m, &count, &sum_x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::calibrate;

    #[test]
    fn analytic_matches_scan_8bit() {
        for h in [3u32, 5] {
            for m in [0u32, 4, 8] {
                let scan = calibrate(8, h, m);
                let ana = calibrate_analytic(8, h, m);
                assert!(
                    (scan.alpha - ana.alpha).abs() < 1e-10,
                    "h={h}: alpha {} vs {}",
                    scan.alpha,
                    ana.alpha
                );
                assert_eq!(scan.delta_ee, ana.delta_ee);
                for (a, b) in scan.c.iter().zip(&ana.c) {
                    assert!((a - b).abs() < 1e-10, "C: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn analytic_matches_scan_16bit() {
        let scan = calibrate(16, 6, 8);
        let ana = calibrate_analytic(16, 6, 8);
        assert!((scan.alpha - ana.alpha).abs() < 1e-9);
        for (a, b) in scan.c.iter().zip(&ana.c) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn thirty_two_bit_calibration_is_instant() {
        // The paper's "impractical" case: full 32-bit calibration.
        let t0 = std::time::Instant::now();
        let p = calibrate_analytic(32, 6, 8);
        assert!(t0.elapsed().as_millis() < 200, "took {:?}", t0.elapsed());
        assert!(p.alpha > 1.0 && p.alpha < 2.0);
        assert_eq!(p.c.len(), 8);
        // α converges with width: the 32-bit value sits near the 16-bit one.
        let p16 = calibrate_analytic(16, 6, 8);
        assert!((p.alpha - p16.alpha).abs() < 0.02);
    }

    #[test]
    fn class_counts_total_operand_space() {
        let (count, _) = analytic_classes(12, 4);
        let total: f64 = count.iter().sum();
        assert_eq!(total as u64, (1u64 << 12) - 1, "all non-zero operands");
    }
}
