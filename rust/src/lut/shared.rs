//! Shared compensation-LUT registry — the paper's Future Work §V, second
//! direction: *"a centralized or shared LUT architecture, where multiple
//! scaleTRIM units access common compensation data through lightweight
//! indexing"*.
//!
//! Many scaleTRIM instances (e.g. one per MAC column of an accelerator)
//! with the same (bits, h, M) share one calibrated table. The registry
//! deduplicates the constants, hands out cheap `Arc` handles, and tracks
//! how much storage the sharing saves — the area/memory benefit §V
//! anticipates.

use super::calib::ScaleTrimParams;
use crate::calib::CalibStrategy;
use crate::util::sync::lock_unpoisoned;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One shared compensation table (the constants `Arc` is shared with the
/// unified calibration cache — one allocation per key, process-wide).
#[derive(Debug)]
pub struct SharedLut {
    /// The calibrated constants.
    pub params: Arc<ScaleTrimParams>,
}

/// Registry statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SharingStats {
    /// Distinct tables materialised.
    pub distinct_tables: usize,
    /// Total handles outstanding (instances served).
    pub handles: usize,
    /// Bytes a dedicated-LUT design would store (16-bit words × M × N).
    pub dedicated_bytes: usize,
    /// Bytes actually stored.
    pub shared_bytes: usize,
}

impl SharingStats {
    /// Fractional storage saving.
    pub fn saving(&self) -> f64 {
        if self.dedicated_bytes == 0 {
            0.0
        } else {
            1.0 - self.shared_bytes as f64 / self.dedicated_bytes as f64
        }
    }
}

/// Process-wide shared-LUT registry.
#[derive(Default)]
pub struct LutRegistry {
    tables: Mutex<HashMap<(u32, u32, u32), Arc<SharedLut>>>,
    handles: Mutex<usize>,
}

impl LutRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Acquire the shared table for `(bits, h, m)`, calibrating on first
    /// use. Constants resolve through the unified calibration cache
    /// ([`crate::calib::cache()`]), so registry tables, `ScaleTrim`
    /// instances and warm-start artifact loads all share one calibration
    /// per key — the §V sharing statistics come along for free.
    pub fn acquire(&self, bits: u32, h: u32, m: u32) -> Arc<SharedLut> {
        // Entry-API insertion is all-or-nothing and the handle counter is a
        // single add, so poison recovery cannot observe partial state.
        let mut t = lock_unpoisoned(&self.tables);
        *lock_unpoisoned(&self.handles) += 1;
        t.entry((bits, h, m))
            .or_insert_with(|| {
                Arc::new(SharedLut {
                    params: crate::calib::cache().scaletrim_params(
                        bits,
                        h,
                        m,
                        CalibStrategy::Exhaustive,
                    ),
                })
            })
            .clone()
    }

    /// Sharing statistics (each compensation word is 16 bits, Sec. III-B).
    pub fn stats(&self) -> SharingStats {
        let t = lock_unpoisoned(&self.tables);
        let handles = *lock_unpoisoned(&self.handles);
        let shared_bytes: usize = t.values().map(|l| l.params.c_fixed.len() * 2).sum();
        // A dedicated design stores one table per handle.
        let mut dedicated = 0usize;
        for lut in t.values() {
            let per = lut.params.c_fixed.len() * 2;
            // handles are not tracked per-key; approximate by equal split.
            dedicated += per;
        }
        let dedicated_bytes = if t.is_empty() {
            0
        } else {
            dedicated / t.len() * handles
        };
        SharingStats {
            distinct_tables: t.len(),
            handles,
            dedicated_bytes,
            shared_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_dedupes() {
        let reg = LutRegistry::new();
        let a = reg.acquire(8, 3, 4);
        let b = reg.acquire(8, 3, 4);
        assert!(Arc::ptr_eq(&a, &b), "same key must share one table");
        let c = reg.acquire(8, 4, 8);
        assert!(!Arc::ptr_eq(&a, &c));
        let s = reg.stats();
        assert_eq!(s.distinct_tables, 2);
        assert_eq!(s.handles, 3);
    }

    #[test]
    fn sharing_saves_storage() {
        let reg = LutRegistry::new();
        for _ in 0..64 {
            reg.acquire(8, 4, 8); // 64 MAC units, one config
        }
        let s = reg.stats();
        assert_eq!(s.distinct_tables, 1);
        assert_eq!(s.shared_bytes, 8 * 2);
        assert_eq!(s.dedicated_bytes, 64 * 8 * 2);
        assert!(s.saving() > 0.98, "saving {}", s.saving());
    }

    #[test]
    fn shared_params_are_correct() {
        let reg = LutRegistry::new();
        let l = reg.acquire(8, 3, 4);
        let direct = crate::lut::calibrate(8, 3, 4);
        assert_eq!(l.params.c_fixed, direct.c_fixed);
        assert_eq!(l.params.delta_ee, direct.delta_ee);
        // And the allocation is the unified cache's, not a private copy.
        let cached =
            crate::calib::cache().scaletrim_params(8, 3, 4, CalibStrategy::Exhaustive);
        assert!(Arc::ptr_eq(&l.params, &cached));
    }
}
