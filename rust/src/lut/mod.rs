//! Offline calibration flow of Sec. III: linearization (α, ΔEE) and the
//! piecewise-constant compensation LUT (C_i). Everything here runs at *design
//! time* — the deployed multiplier only carries the resulting constants,
//! exactly like the paper's hardwired LUT (Sec. III-D).

mod analytic;
mod calib;
mod shared;

pub use analytic::{analytic_classes, calibrate_analytic};
pub use shared::{LutRegistry, SharedLut, SharingStats};
pub use calib::{
    cached_params, calibrate, paper_table7_params, OperandClasses, ScaleTrimParams,
    COMP_FRAC_BITS,
};
