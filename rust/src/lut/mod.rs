//! Offline calibration flow of Sec. III: linearization (α, ΔEE) and the
//! piecewise-constant compensation LUT (C_i). Everything here runs at *design
//! time* — the deployed multiplier only carries the resulting constants,
//! exactly like the paper's hardwired LUT (Sec. III-D).
//!
//! Caching and persistence of these constants live in the unified
//! calibration plane ([`crate::calib`]): the per-`(bits, h, m)` process
//! cache that used to sit here (`cached_params`) is replaced by
//! [`crate::calib::CalibCache`], keyed on the typed
//! `(DesignSpec, bits, strategy, kind)` identity and warm-startable from
//! the on-disk artifact store.

mod analytic;
mod calib;
mod shared;

pub use analytic::{analytic_classes, calibrate_analytic};
pub use calib::{
    calibrate, paper_table7_params, OperandClasses, ScaleTrimParams, COMP_FRAC_BITS,
};
pub(crate) use calib::segment_of;
pub use shared::{LutRegistry, SharedLut, SharingStats};
