//! Exact multiplier — the accuracy reference (`M_ACC` in Eq. 3) and the
//! baseline row of Figs. 15/16 ("8-bit Accurate multiplier").

use super::{ApproxMultiplier, DesignSpec};

/// Exact `n`-bit unsigned multiplier.
#[derive(Debug, Clone)]
pub struct Exact {
    bits: u32,
}

impl Exact {
    /// New exact multiplier of width `bits`.
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 2 && bits <= 32);
        Self { bits }
    }
}

impl ApproxMultiplier for Exact {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Exact { bits: self.bits }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        a * b
    }

    /// Batch kernel: a plain multiply loop the compiler auto-vectorises —
    /// the throughput ceiling every approximate design is measured against.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = x * y;
        }
    }

    /// Lane kernel: straight-line `x·y` per lane (lowers to `vpmuludq`
    /// blocks) — the SIMD throughput ceiling the approximate lane kernels
    /// are compared against in the bench trajectory.
    fn mul_batch_simd(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        use crate::simd;
        simd::drive_lanes(
            a,
            b,
            out,
            |xa, xb| {
                let mut r = [0u64; simd::LANES];
                for ((r_i, x), y) in r.iter_mut().zip(xa.iter()).zip(xb.iter()) {
                    *r_i = x * y;
                }
                r
            },
            |ta, tb, tout| self.mul_batch(ta, tb, tout),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_is_exact() {
        let m = Exact::new(8);
        for a in 0..256u64 {
            for b in (0..256u64).step_by(17) {
                assert_eq!(m.mul(a, b), a * b);
            }
        }
    }
}
