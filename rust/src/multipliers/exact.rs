//! Exact multiplier — the accuracy reference (`M_ACC` in Eq. 3) and the
//! baseline row of Figs. 15/16 ("8-bit Accurate multiplier").

use super::ApproxMultiplier;

/// Exact `n`-bit unsigned multiplier.
#[derive(Debug, Clone)]
pub struct Exact {
    bits: u32,
}

impl Exact {
    /// New exact multiplier of width `bits`.
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 2 && bits <= 32);
        Self { bits }
    }
}

impl ApproxMultiplier for Exact {
    fn name(&self) -> String {
        format!("Exact{}", self.bits)
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        a * b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_is_exact() {
        let m = Exact::new(8);
        for a in 0..256u64 {
            for b in (0..256u64).step_by(17) {
                assert_eq!(m.mul(a, b), a * b);
            }
        }
    }
}
