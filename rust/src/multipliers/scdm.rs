//! SCDM — Signed Carry-Disregard Multiplier family (Shakibhamedan et al.,
//! TCAS-I 2024, "ACE-CNN"; paper ref [19]), evaluated here in its unsigned
//! magnitude form (the paper's DNN flow uses sign-magnitude wrapping).
//!
//! An array multiplier in which carry propagation is *disregarded* in the
//! `k` least-significant result columns: each of those columns keeps only
//! the sum bit of its partial products; the carries that would ripple into
//! higher columns are dropped. Columns ≥ `k` accumulate exactly.

use super::{ApproxMultiplier, DesignSpec};

/// SCDM-k behavioural model.
#[derive(Debug, Clone)]
pub struct Scdm {
    bits: u32,
    k: u32,
}

impl Scdm {
    /// New SCDM disregarding carries in the `k` low columns (k < 2·bits).
    pub fn new(bits: u32, k: u32) -> Self {
        assert!(k < 2 * bits);
        Self { bits, k }
    }
}

impl ApproxMultiplier for Scdm {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Scdm {
            bits: self.bits,
            k: self.k,
        }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    fn mul(&self, a: u64, b: u64) -> u64 {
        let n = self.bits;
        // Column population counts of the partial-product matrix.
        let mut result = 0u64;
        let mut carry = 0u64;
        for col in 0..(2 * n - 1) {
            let mut count = carry;
            let lo = col.saturating_sub(n - 1);
            let hi = col.min(n - 1);
            debug_assert!(col < u64::BITS, "result column exceeds the u64 range");
            for i in lo..=hi {
                let j = col - i;
                debug_assert!(i < n && j < n, "partial-product index exceeds the operand width");
                count += ((a >> i) & 1) & ((b >> j) & 1);
            }
            result |= (count & 1) << col;
            if col < self.k {
                carry = 0; // carries disregarded in the low columns
            } else {
                carry = count >> 1;
            }
        }
        // Remaining carry spills into the top column(s).
        result + (carry << (2 * n - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    #[test]
    fn k0_is_exact() {
        let m = Scdm::new(8, 0);
        for a in (0..256u64).step_by(3) {
            for b in (0..256u64).step_by(7) {
                assert_eq!(m.mul(a, b), a * b, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn error_grows_with_k() {
        let mred = |k: u32| {
            let m = Scdm::new(8, k);
            let mut s = 0f64;
            for a in 1..256u64 {
                for b in 1..256u64 {
                    let e = (a * b) as f64;
                    s += ((m.mul(a, b) as f64 - e) / e).abs();
                }
            }
            100.0 * s / (255.0 * 255.0)
        };
        let m2 = mred(2);
        let m4 = mred(4);
        let m6 = mred(6);
        assert!(m2 < m4 && m4 < m6, "{m2} {m4} {m6}");
        // AXM8-3-class accuracy for k=4 (paper SCDM points sit near 2–3%).
        assert!(m4 < 5.0, "SCDM-4 MRED {m4:.2} out of family");
    }

    #[test]
    fn high_columns_unaffected() {
        // With k=4 the top product bits of large operands stay close.
        let m = Scdm::new(8, 4);
        let p = m.mul(255, 255);
        assert!((p as i64 - (255 * 255) as i64).abs() < 64);
    }
}
