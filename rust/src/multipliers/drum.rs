//! DRUM — Dynamic Range Unbiased Multiplier (Hashemi, Bahar, Reda,
//! ICCAD 2015; paper ref [11]).
//!
//! Each operand keeps its `m` most significant bits from the leading-one
//! position down, the LSB of the kept window is forced to `1` (unbiasing —
//! the expected value of the discarded tail), the rest is zeroed, and the two
//! reduced operands feed an exact `m×m` multiplier plus a shift.

use super::{leading_one, ApproxMultiplier, DesignSpec};

/// DRUM(m) behavioural model.
#[derive(Debug, Clone)]
pub struct Drum {
    bits: u32,
    m: u32,
}

impl Drum {
    /// New DRUM with window width `m` (paper evaluates m ∈ 3..=7 at 8-bit).
    pub fn new(bits: u32, m: u32) -> Self {
        assert!(m >= 2 && m <= bits);
        Self { bits, m }
    }

    /// The reduced operand: `m`-bit leading window with forced LSB.
    #[inline]
    fn reduce(&self, v: u64) -> u64 {
        if v == 0 {
            return 0;
        }
        let n = leading_one(v);
        let width = n + 1; // significant bits
        if width <= self.m {
            v
        } else {
            let shift = width - self.m;
            debug_assert!(shift < self.bits, "window shift exceeds the declared width");
            ((v >> shift) | 1) << shift
        }
    }
}

impl ApproxMultiplier for Drum {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Drum { m: self.m }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        self.reduce(a) * self.reduce(b)
    }

    /// Monomorphized batch kernel: `self` is concrete here, so the
    /// `#[inline]` reduce/multiply body inlines statically and the window
    /// width `m` stays in a register across the loop.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = self.reduce(x) * self.reduce(y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    #[test]
    fn small_operands_pass_through() {
        let d = Drum::new(8, 4);
        // width <= m: untouched, so products of small values are exact.
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(d.mul(a, b), a * b);
            }
        }
    }

    #[test]
    fn reduction_forces_lsb() {
        let d = Drum::new(8, 3);
        // 0b11011010 (218): window = 0b110, shift 5, LSB forced -> 0b111<<5
        assert_eq!(d.reduce(0b1101_1010), 0b111 << 5);
        // 0b1000_0000 (128): window 0b100 -> forced 0b101<<5 = 160
        assert_eq!(d.reduce(128), 0b101 << 5);
    }

    #[test]
    fn unbiased_mean_error_near_zero() {
        // DRUM's design goal: (near-)zero mean error over the full space.
        let d = Drum::new(8, 4);
        let mut sum = 0f64;
        let mut n = 0u64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                sum += d.mul(a, b) as f64 - (a * b) as f64;
                n += 1;
            }
        }
        let mean_rel = sum / n as f64 / 16384.0;
        assert!(mean_rel.abs() < 0.01, "mean error not unbiased: {mean_rel}");
    }

    #[test]
    fn mred_matches_paper_anchor() {
        // Table 4: DRUM(3)=12.62, DRUM(4)=6.03, DRUM(6)=2.43. The textbook
        // DRUM datapath reproduces m=3..5 closely; Table 4's m=6..7 rows sit
        // *above* the original DRUM paper's own numbers, so the assertion is
        // match-or-beat there (our DRUM(6) measures 1.30).
        for (m, paper, tol) in [(3u32, 12.62f64, 1.0), (4, 6.03, 0.7), (6, 2.43, f64::NAN)] {
            let d = Drum::new(8, m);
            let mut s = 0f64;
            for a in 1..256u64 {
                for b in 1..256u64 {
                    let e = (a * b) as f64;
                    s += ((d.mul(a, b) as f64 - e) / e).abs();
                }
            }
            let mred = 100.0 * s / (255.0 * 255.0);
            if tol.is_nan() {
                assert!(mred <= paper + 0.3, "DRUM({m}): {mred:.2} vs paper {paper}");
            } else {
                assert!(
                    (mred - paper).abs() < tol,
                    "DRUM({m}): MRED {mred:.2} vs paper {paper}"
                );
            }
        }
    }
}
