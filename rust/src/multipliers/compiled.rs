//! `CompiledMul` — a table-backed kernel on the batched plane: folds *any*
//! behavioural design into its full `2^n × 2^n` product table so every
//! subsequent multiply is a single load. Built once via `mul_batch_simd`
//! (the kernel plane compiles itself), usable anywhere an [`ApproxMultiplier`]
//! is: repeat-evaluation paths (DSE re-sweeps, calibration scans, serving
//! lanes that re-characterise a config) trade one up-front pass over the
//! operand space for pure-load steady-state throughput.
//!
//! Memory: `4·2^2n` bytes (products of `n ≤ 12`-bit designs fit `u32`) —
//! 256 KiB at 8 bits, 67 MiB at the 12-bit ceiling. Wider spaces cannot be
//! tabulated; [`CompiledMul::compile`] asserts the bound.

use super::{ApproxMultiplier, DesignSpec};

/// Product-table kernel compiled from a behavioural design.
#[derive(Debug, Clone)]
pub struct CompiledMul {
    /// Identity of the source design — a compiled table is observably
    /// identical to its source, so it shares the source's spec (and
    /// therefore its LUT-cache slot and hardware model).
    spec: DesignSpec,
    name: String,
    bits: u32,
    /// Calibration identity of the source design (mirrored so the table
    /// shares the source's calibration-cache slots, not the default's).
    calib: crate::calib::CalibStrategy,
    calib_cost: f64,
    /// Row-major full product table: `table[(a << bits) | b] = mul(a, b)`.
    table: Vec<u32>,
}

impl CompiledMul {
    /// Widest operand space that can be tabulated (`2^24` entries, 67 MiB);
    /// matches the sweep layer's exhaustive-traversal ceiling.
    pub const MAX_BITS: u32 = 12;

    /// Tabulate `m` over its full operand space through the batched plane.
    ///
    /// Panics when `m.bits() > MAX_BITS` (the table would exceed 67 MiB)
    /// or if the design produces a product that does not fit 32 bits
    /// (impossible for any sane `n ≤ 12`-bit design: exact peak is `2^24`).
    pub fn compile(m: &dyn ApproxMultiplier) -> Self {
        let bits = m.bits();
        assert!(
            bits <= Self::MAX_BITS,
            "CompiledMul: {} is {bits}-bit; tables beyond {} bits exceed 67 MiB",
            m.name(),
            Self::MAX_BITS
        );
        let n = 1usize << bits;
        let mut table = vec![0u32; n * n];
        let b_ops: Vec<u64> = (0..n as u64).collect();
        let mut a_ops = vec![0u64; n];
        let mut out = vec![0u64; n];
        for a in 0..n as u64 {
            a_ops.fill(a);
            // Compile through the SIMD plane — the fastest kernel the
            // source design offers (falls back to its `mul_batch`).
            m.mul_batch_simd(&a_ops, &b_ops, &mut out);
            let row = &mut table[(a as usize) * n..(a as usize + 1) * n];
            for (slot, &p) in row.iter_mut().zip(out.iter()) {
                assert!(p <= u32::MAX as u64, "{}: product {p} overflows u32", m.name());
                *slot = p as u32;
            }
        }
        Self {
            spec: m.spec(),
            name: format!("compiled[{}]", m.name()),
            bits,
            calib: m.calib_strategy(),
            calib_cost: m.calib_cost_ops(),
            table,
        }
    }

    /// Table footprint in bytes.
    pub fn table_bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<u32>()
    }
}

impl ApproxMultiplier for CompiledMul {
    fn spec(&self) -> DesignSpec {
        self.spec
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    fn calib_strategy(&self) -> crate::calib::CalibStrategy {
        self.calib
    }

    fn calib_cost_ops(&self) -> f64 {
        self.calib_cost
    }

    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(self.bits <= Self::MAX_BITS, "table width exceeds the tabulation ceiling");
        self.table[((a as usize) << self.bits) | b as usize] as u64
    }

    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        let bits = self.bits;
        debug_assert!(bits <= Self::MAX_BITS, "table width exceeds the tabulation ceiling");
        let table = &self.table[..];
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = table[((x as usize) << bits) | y as usize] as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::{Exact, ScaleTrim};

    #[test]
    fn compiled_matches_source_over_full_space() {
        let src = ScaleTrim::new(8, 3, 4);
        let c = CompiledMul::compile(&src);
        assert_eq!(c.bits(), 8);
        assert_eq!(c.name(), "compiled[scaleTRIM(3,4)]");
        for a in 0..256u64 {
            for b in 0..256u64 {
                assert_eq!(c.mul(a, b), src.mul(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn compiled_batch_is_pure_loads_and_identical() {
        let src = Exact::new(8);
        let c = CompiledMul::compile(&src);
        let a: Vec<u64> = (0..256).collect();
        let b: Vec<u64> = (0..256).rev().collect();
        let mut out = vec![0u64; 256];
        c.mul_batch(&a, &b, &mut out);
        for i in 0..256 {
            assert_eq!(out[i], a[i] * b[i]);
        }
    }

    #[test]
    fn table_footprint_matches_width() {
        let c = CompiledMul::compile(&Exact::new(8));
        assert_eq!(c.table_bytes(), 256 * 256 * 4);
    }

    #[test]
    #[should_panic(expected = "beyond")]
    fn rejects_untabulatable_width() {
        let _ = CompiledMul::compile(&Exact::new(13));
    }
}
