//! AXM — recursive approximate multiplier for energy-efficient MAC units
//! (Deepsita, Karthikeyan, Mahammad, Integration 2023; paper ref [22],
//! configs AXM8-3 / AXM8-4 in Table 4).
//!
//! The recursive decomposition `A×B = AH·BH·2^n + (AH·BL + AL·BH)·2^(n/2)
//! + AL·BL` is applied down to 2×2 blocks; approximate levels replace the
//! exact 2×2 block with Kulkarni's underdesigned cell (the single error
//! case `3×3 → 7`). `AXM8-3` approximates the lowest recursion level only;
//! `AXM8-4` additionally drops the `AL·BL` sub-product of the top level
//! (more aggressive, cheaper — matches the paper's MRED ordering
//! 2.3 vs 8.7).

use super::{ApproxMultiplier, DesignSpec};

/// AXM8-k behavioural model (k ∈ {3, 4}).
#[derive(Debug, Clone)]
pub struct Axm {
    bits: u32,
    k: u32,
}

impl Axm {
    /// New AXM; `k = 3` (approximate 2×2 cells) or `k = 4` (also drops the
    /// low×low sub-product at the top level).
    pub fn new(bits: u32, k: u32) -> Self {
        assert!(k == 3 || k == 4);
        assert!(bits.is_power_of_two() && (4..=32).contains(&bits));
        Self { bits, k }
    }

    /// Kulkarni's approximate 2×2 cell: exact except 3×3 → 7.
    #[inline]
    fn mul2(a: u64, b: u64) -> u64 {
        if a == 3 && b == 3 {
            7
        } else {
            a * b
        }
    }

    /// Recursive build from approximate 2×2 cells.
    fn mul_rec(a: u64, b: u64, width: u32) -> u64 {
        if width == 2 {
            return Self::mul2(a, b);
        }
        let half = width / 2;
        debug_assert!(
            half < width && width <= u64::BITS / 2,
            "recursion width exceeds the u64 half-datapath"
        );
        let mask = (1u64 << half) - 1;
        let (ah, al) = (a >> half, a & mask);
        let (bh, bl) = (b >> half, b & mask);
        let hh = Self::mul_rec(ah, bh, half);
        let hl = Self::mul_rec(ah, bl, half);
        let lh = Self::mul_rec(al, bh, half);
        let ll = Self::mul_rec(al, bl, half);
        (hh << width) + ((hl + lh) << half) + ll
    }
}

impl ApproxMultiplier for Axm {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Axm {
            bits: self.bits,
            k: self.k,
        }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        let w = self.bits;
        if self.k == 3 {
            Self::mul_rec(a, b, w)
        } else {
            // k = 4: drop AL·BL at the top level, keep approximate blocks
            // elsewhere; compensate with the expected value of the dropped
            // sub-product's MSB behaviour by OR-ing (cheap hardware).
            let half = w / 2;
            debug_assert!(
                half < w && w <= u64::BITS / 2,
                "datapath width exceeds the u64 half-range"
            );
            let mask = (1u64 << half) - 1;
            let (ah, al) = (a >> half, a & mask);
            let (bh, bl) = (b >> half, b & mask);
            let hh = Self::mul_rec(ah, bh, half);
            let hl = Self::mul_rec(ah, bl, half);
            let lh = Self::mul_rec(al, bh, half);
            let ll_approx = al | bl; // carry-free stand-in for AL·BL
            (hh << w) + ((hl + lh) << half) + ll_approx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn kulkarni_cell_single_error() {
        for a in 0..4u64 {
            for b in 0..4u64 {
                let expect = if a == 3 && b == 3 { 7 } else { a * b };
                assert_eq!(Axm::mul2(a, b), expect);
            }
        }
    }

    #[test]
    fn k3_is_more_accurate_than_k4() {
        // Table 4: AXM8-3 MRED 2.3, AXM8-4 MRED 8.7.
        let m3 = mred(&Axm::new(8, 3));
        let m4 = mred(&Axm::new(8, 4));
        assert!(m3 < m4, "AXM-3 {m3:.2} !< AXM-4 {m4:.2}");
        assert!(m3 < 4.5, "AXM-3 MRED {m3:.2} out of family (paper 2.3)");
    }

    #[test]
    fn exact_when_no_threes_involved() {
        // Operands whose 2-bit digits never form (3,3) multiply exactly
        // under k=3.
        let m = Axm::new(8, 3);
        assert_eq!(m.mul(0b10101010, 0b01010101), 0b10101010 * 0b01010101);
    }
}
