//! MBM — Minimally Biased Multiplier (Saadat, Bokhari, Parameswaran,
//! TCAD 2018; paper ref [7]): a Mitchell logarithmic multiplier over
//! LSB-truncated operands with a single design-time bias constant that
//! centres the error distribution ("add a fixed value", Table 1).
//!
//! `MBM-k` truncates `k−1` least-significant bits of each operand at a
//! fixed position before the logarithmic approximation; the bias constant
//! is calibrated offline over the full operand space (cached per config).

use super::{leading_one, narrow_result, ApproxMultiplier, DesignSpec};
use std::collections::HashMap;
use std::sync::Mutex;

/// MBM-k behavioural model.
#[derive(Debug, Clone)]
pub struct Mbm {
    bits: u32,
    k: u32,
    /// Calibrated bias in units of 2^-F of the normalised term.
    bias_fixed: i64,
}

const F: u32 = 20;

impl Mbm {
    /// New MBM-k (paper evaluates k ∈ 1..=5 at 8-bit).
    pub fn new(bits: u32, k: u32) -> Self {
        assert!(k >= 1 && k < bits);
        let bias_fixed = cached_bias(bits, k);
        Self {
            bits,
            k,
            bias_fixed,
        }
    }

    /// Raw (bias-free) log-approximate product of the truncated operands.
    #[inline]
    fn raw(&self, a: u64, b: u64) -> Option<(u128, u32)> {
        let d = self.k - 1;
        debug_assert!(d < self.bits, "truncation distance exceeds the operand width");
        let at = (a >> d) << d;
        let bt = (b >> d) << d;
        if at == 0 || bt == 0 {
            return None;
        }
        let na = leading_one(at);
        let nb = leading_one(bt);
        debug_assert!(na < F && nb < F, "leading-one position exceeds the F-bit datapath");
        let x = ((at - (1 << na)) as u128) << (F - na);
        let y = ((bt - (1 << nb)) as u128) << (F - nb);
        let s = x + y;
        let one = 1u128 << F;
        let term = if s < one { one + s } else { s << 1 };
        Some((term, na + nb))
    }
}

impl ApproxMultiplier for Mbm {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Mbm { k: self.k }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        match self.raw(a, b) {
            None => 0,
            Some((term, shift)) => {
                debug_assert!(shift <= 2 * (self.bits - 1), "output shift exceeds double width");
                let biased = (term as i128 + self.bias_fixed as i128).max(0) as u128;
                narrow_result(biased << shift, F)
            }
        }
    }

    /// Monomorphized batch kernel: the truncation distance `k − 1` and the
    /// calibrated bias constant are hoisted out of the loop.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        let d = self.k - 1;
        debug_assert!(d < self.bits, "truncation distance exceeds the operand width");
        let bias = self.bias_fixed as i128;
        let one = 1u128 << F;
        for ((&av, &bv), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            let at = (av >> d) << d;
            let bt = (bv >> d) << d;
            *o = if at == 0 || bt == 0 {
                0
            } else {
                let na = leading_one(at);
                let nb = leading_one(bt);
                debug_assert!(na < F && nb < F, "leading-one exceeds the F-bit datapath");
                let x = ((at - (1 << na)) as u128) << (F - na);
                let y = ((bt - (1 << nb)) as u128) << (F - nb);
                let s = x + y;
                let term = if s < one { one + s } else { s << 1 };
                let biased = (term as i128 + bias).max(0) as u128;
                narrow_result(biased << (na + nb), F)
            };
        }
    }
}

/// Offline bias calibration: the constant (in normalised-term units) that
/// zeroes the mean error over the full operand space — "minimally biased".
fn cached_bias(bits: u32, k: u32) -> i64 {
    static CACHE: Mutex<Option<HashMap<(u32, u32), i64>>> = Mutex::new(None);
    debug_assert!(bits < u64::BITS, "operand width exceeds the u64 sweep datapath");
    // Entry-API insertion is all-or-nothing, so a panicking calibration
    // leaves the map consistent — poison recovery is sound.
    let mut guard = crate::util::sync::lock_unpoisoned(&CACHE);
    let map = guard.get_or_insert_with(HashMap::new);
    *map.entry((bits, k)).or_insert_with(|| {
        let probe = Mbm {
            bits,
            k,
            bias_fixed: 0,
        };
        // Mean of (exact - raw)/2^(na+nb) over the space, in 2^-F units.
        // Exhaustive up to 10-bit; deterministic 4M-pair sample above that
        // (the 16-bit space has 2^32 pairs).
        let mut sum = 0f64;
        let mut n = 0u64;
        let mut visit = |a: u64, b: u64| {
            if let Some((term, shift)) = probe.raw(a, b) {
                debug_assert!(shift < u64::BITS, "output shift exceeds the u64 range");
                let exact_term = (a * b) as f64 / (1u64 << shift) as f64;
                sum += exact_term - term as f64 / (1u64 << F) as f64;
                n += 1;
            }
        };
        if bits <= 10 {
            for a in 1u64..(1 << bits) {
                for b in 1u64..(1 << bits) {
                    visit(a, b);
                }
            }
        } else {
            let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(0x4D42_4D31);
            for _ in 0..4_000_000 {
                visit(rng.gen_operand(bits), rng.gen_operand(bits));
            }
        }
        ((sum / n as f64) * (1u64 << F) as f64).round() as i64
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn bias_centres_error() {
        let m = Mbm::new(8, 1);
        let mut sum = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                sum += m.mul(a, b) as f64 - (a * b) as f64;
            }
        }
        let mean = sum / (255.0 * 255.0);
        // Mean absolute product is ~16k; the bias keeps |mean error| tiny.
        assert!(mean.abs() < 120.0, "mean error {mean} not centred");
    }

    #[test]
    fn mbm1_matches_paper() {
        // Table 4: MBM-1 MRED = 2.80; ours 2.7–2.8.
        let got = mred(&Mbm::new(8, 1));
        assert!((got - 2.80).abs() < 0.25, "MBM-1 MRED {got:.2} vs 2.80");
    }

    #[test]
    fn truncation_degrades_monotonically() {
        let m1 = mred(&Mbm::new(8, 1));
        let m3 = mred(&Mbm::new(8, 3));
        let m5 = mred(&Mbm::new(8, 5));
        assert!(m1 < m3 && m3 < m5, "{m1} {m3} {m5}");
    }

    #[test]
    fn zero_stays_zero() {
        let m = Mbm::new(8, 3);
        assert_eq!(m.mul(0, 77), 0);
        // operands that truncate to zero also produce zero
        assert_eq!(m.mul(3, 77), 0); // 3 >> 2 == 0 for k=3
    }
}
