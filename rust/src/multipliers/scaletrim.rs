//! scaleTRIM(h, M) — the paper's proposed multiplier (Sec. III).
//!
//! Datapath, mirroring the hardware of Fig. 8:
//!
//! 1. **Zero detection** — either operand zero forces a zero output.
//! 2. **LOD** — leading-one positions `n_A`, `n_B`.
//! 3. **Truncation** — `X_h`, `Y_h`: top `h` fraction bits below the leading
//!    one, zero-padded when fewer exist.
//! 4. **Shift-Add approximation** — `S = X_h + Y_h`;
//!    `lin = S + 2^ΔEE·S` realised as one add plus one hardwired shift.
//! 5. **Compensation** — LUT constant `C_i` selected by the top ⌈log2 M⌉
//!    bits of `S`, added in (16-bit constants, Sec. III-B).
//! 6. **Output shift** — result = `2^(n_A+n_B) · (1 + lin + C_i)`, computed
//!    in fixed point with `COMP_FRAC_BITS` fraction bits and truncated like
//!    the hardware.
//!
//! Constants (α, ΔEE, C_i) come from the design-time calibration plane
//! ([`crate::calib`]): the selected [`CalibStrategy`] resolves through the
//! process-wide [`CalibCache`](crate::calib::CalibCache) (warm-startable
//! from the on-disk artifact store), so N instances of one configuration
//! share a single calibration — and a single constants allocation.

use super::{leading_one, narrow_result, truncate_fraction, ApproxMultiplier, DesignSpec};
use crate::calib::{calibrator, CalibStrategy};
use crate::lut::{ScaleTrimParams, COMP_FRAC_BITS};
use std::sync::Arc;

/// scaleTRIM(h, M) behavioural model at a given bit-width.
#[derive(Debug, Clone)]
pub struct ScaleTrim {
    bits: u32,
    strategy: CalibStrategy,
    params: Arc<ScaleTrimParams>,
}

impl ScaleTrim {
    /// Construct (and calibrate, on first use per `(bits, h, M)`) a
    /// scaleTRIM instance with the paper's exhaustive calibration.
    /// `m == 0` disables compensation (paper ST(h,0)). Panics on invalid
    /// parameters — [`ScaleTrim::try_new`] is the typed form.
    pub fn new(bits: u32, h: u32, m: u32) -> Self {
        // lint:allow(no-panic): documented panicking constructor; try_new is the typed form
        Self::try_new(bits, h, m).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`ScaleTrim::new`] as a typed error: validity is decided by
    /// [`DesignSpec::validate`], the same path `DesignSpec::build` takes —
    /// direct construction and spec-driven construction agree by
    /// construction.
    pub fn try_new(bits: u32, h: u32, m: u32) -> crate::Result<Self> {
        Self::with_strategy(bits, h, m, CalibStrategy::Exhaustive)
    }

    /// Construct under an explicit calibration strategy (the
    /// accuracy-vs-calibration-cost axis). [`CalibStrategy::Quantile`]
    /// selects the `scaleTRIM-Q` design — non-uniform segment boundaries,
    /// distinct [`DesignSpec`] identity; the other strategies are
    /// different ways of computing the same scaleTRIM(h, M) constants.
    pub fn with_strategy(
        bits: u32,
        h: u32,
        m: u32,
        strategy: CalibStrategy,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            strategy != CalibStrategy::External,
            "CalibStrategy::External tags externally supplied constants — \
             use ScaleTrim::with_params to provide them"
        );
        let spec = if strategy == CalibStrategy::Quantile {
            DesignSpec::ScaleTrimQ { h, m }
        } else {
            DesignSpec::ScaleTrim { h, m }
        };
        spec.validate(bits)?;
        Ok(Self {
            bits,
            strategy,
            params: crate::calib::cache().scaletrim_params(bits, h, m, strategy),
        })
    }

    /// Construct from externally supplied constants (used by tests and by
    /// the artifact replay paths; skips calibration but not validation —
    /// a ΔEE below `h − F` would underflow the linearization shift, see
    /// [`ScaleTrimParams::validate`]). The instance's calibration identity
    /// is [`CalibStrategy::External`]: unknown provenance, so it never
    /// shares a strategy-keyed cache slot (product LUTs included) with the
    /// self-calibrated configs — external constants can differ from them
    /// without poisoning anything. The *design family* still follows the
    /// constants: non-empty `seg_bounds` makes `spec()` report
    /// `scaleTRIM-Q`.
    pub fn with_params(bits: u32, params: ScaleTrimParams) -> Self {
        params.validate();
        Self {
            bits,
            strategy: CalibStrategy::External,
            params: Arc::new(params),
        }
    }

    /// Calibrated constants (α, ΔEE, C_i).
    pub fn params(&self) -> &ScaleTrimParams {
        &self.params
    }

    /// Truncation width h.
    pub fn h(&self) -> u32 {
        self.params.h
    }

    /// Segment count M (0 = no compensation).
    pub fn m(&self) -> u32 {
        self.params.m
    }

    /// The calibration strategy that produced the constants.
    pub fn strategy(&self) -> CalibStrategy {
        self.strategy
    }

    /// The linearization shift realising `2^ΔEE·S` as one hardwired shift
    /// in `COMP_FRAC_BITS` fixed point (`F − h + ΔEE`; ΔEE folds in).
    /// Non-negative by construction — [`ScaleTrimParams::validate`] pins
    /// `ΔEE ≥ h − F` on every constants-entry path.
    #[inline(always)]
    fn lin_shift(&self) -> u32 {
        const F: u32 = COMP_FRAC_BITS;
        debug_assert!(
            self.params.h <= F && F as i32 - self.params.h as i32 + self.params.delta_ee >= 0,
            "linearization shift underflow: ΔEE {} < h − F (validated at construction)",
            self.params.delta_ee
        );
        (F as i32 - self.params.h as i32 + self.params.delta_ee) as u32
    }
}

/// Linearization term `1 + S + 2^ΔEE·S` in `COMP_FRAC_BITS` fixed point
/// (Sec. III-A Eq. 6, one adder + one hardwired shift; `lin_shift` already
/// folds ΔEE). The single source of the term for all three kernel paths —
/// scalar [`ScaleTrim::mul`], the batched loop, and the SIMD lane kernel —
/// so they cannot drift.
#[inline(always)]
fn lin_term(s: u64, h: u32, lin_shift: u32) -> i64 {
    const F: u32 = COMP_FRAC_BITS;
    debug_assert!(
        h <= F && lin_shift < i64::BITS && s < (1u64 << (h + 1)),
        "linearization inputs exceed the i64 datapath"
    );
    (1i64 << F) + ((s as i64) << (F - h)) + ((s as i64) << lin_shift)
}

impl ApproxMultiplier for ScaleTrim {
    fn spec(&self) -> DesignSpec {
        // The design family is decided by the constants' segmentation
        // shape, not the strategy tag — so external quantile-shaped
        // constants still identify as scaleTRIM-Q (and validation pins
        // shape ⇔ family everywhere constants can enter).
        if self.params.seg_bounds.is_empty() {
            DesignSpec::ScaleTrim {
                h: self.params.h,
                m: self.params.m,
            }
        } else {
            DesignSpec::ScaleTrimQ {
                h: self.params.h,
                m: self.params.m,
            }
        }
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    fn calib_strategy(&self) -> CalibStrategy {
        self.strategy
    }

    fn calib_cost_ops(&self) -> f64 {
        if self.strategy == CalibStrategy::External {
            // Unknown provenance: no design-time cost to model.
            0.0
        } else {
            calibrator(self.strategy).cost_ops(self.bits, self.params.h)
        }
    }

    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < (1u64 << self.bits) && b < (1u64 << self.bits));
        // (1) zero-detection bypass (Fig. 8a).
        if a == 0 || b == 0 {
            return 0;
        }
        let h = self.params.h;
        const F: u32 = COMP_FRAC_BITS;

        // (2) LOD.
        let na = leading_one(a);
        let nb = leading_one(b);
        debug_assert!(
            na < self.bits && nb < self.bits,
            "leading-one position exceeds the declared width"
        );

        // (3) truncation to X_h, Y_h (units of 2^-h).
        let xh = truncate_fraction(a, na, h);
        let yh = truncate_fraction(b, nb, h);
        let s = xh + yh; // S = X_h + Y_h, units 2^-h, in [0, 2)

        // (4) shift-add approximation in F-bit fixed point:
        //     term = 1 + S + 2^ΔEE·S   (one adder + one hardwired shift).
        let mut term = lin_term(s, h, self.lin_shift());

        // (5) LUT compensation (selected by the MSBs of S).
        if self.params.m > 0 {
            term += self.params.c_fixed[self.params.segment(s)];
        }

        // (6) output shift by n_A + n_B, truncating the F fraction bits.
        // (§Perf note: a u64 fast path for the final shift measured neutral
        // to slightly negative — reverted; the u128 shift is not the
        // bottleneck. See EXPERIMENTS.md §Perf iteration log.)
        debug_assert!(term >= 0, "compensated term left the nonnegative mantissa range");
        narrow_result((term as u128) << (na + nb), F)
    }

    /// Monomorphized batch kernel: the calibrated constants (`h`, the
    /// linearization shift folding `ΔEE`, the compensation-LUT base
    /// pointer) are hoisted out of the loop, so the per-pair body is pure
    /// datapath with no parameter reloads and no dynamic dispatch.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        const F: u32 = COMP_FRAC_BITS;
        let h = self.params.h;
        let m = self.params.m;
        let c_fixed = &self.params.c_fixed[..];
        let lin_shift = self.lin_shift();
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            debug_assert!(x < (1u64 << self.bits) && y < (1u64 << self.bits));
            *o = if x == 0 || y == 0 {
                0
            } else {
                let na = leading_one(x);
                let nb = leading_one(y);
                debug_assert!(
                    na < self.bits && nb < self.bits,
                    "leading-one position exceeds the declared width"
                );
                let s = truncate_fraction(x, na, h) + truncate_fraction(y, nb, h);
                let mut term = lin_term(s, h, lin_shift);
                if m > 0 {
                    term += c_fixed[self.params.segment(s)];
                }
                debug_assert!(term >= 0, "compensated term left the nonnegative mantissa range");
                narrow_result((term as u128) << (na + nb), F)
            };
        }
    }

    /// Hand-vectorized lane kernel: the full scaleTRIM datapath evaluated
    /// over [`simd::LANES`]-wide branch-free blocks. The per-pair
    /// `x == 0 || y == 0` branch of the scalar kernels — unpredictable on
    /// zero-heavy post-ReLU streams — becomes branchless pre-masking:
    /// zero lanes compute on placeholder operand `1` (LOD 0, empty
    /// fraction) and the result lane is multiplied by the nonzero flag.
    /// Term math is [`lin_term`], shared with `mul`/`mul_batch`; the
    /// sub-lane tail delegates to `mul_batch`.
    fn mul_batch_simd(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        use crate::simd;
        const F: u32 = COMP_FRAC_BITS;
        let h = self.params.h;
        let m = self.params.m;
        let params = &*self.params;
        let lin_shift = self.lin_shift();
        simd::drive_lanes(
            a,
            b,
            out,
            |xa, xb| {
                let keep = simd::nonzero_flags(xa, xb);
                let xm = simd::mask_zero_to_one(xa);
                let ym = simd::mask_zero_to_one(xb);
                let na = simd::leading_one_lanes(&xm);
                let nb = simd::leading_one_lanes(&ym);
                let mut r = [0u64; simd::LANES];
                for (i, r_i) in r.iter_mut().enumerate() {
                    debug_assert!(
                        na[i] < self.bits && nb[i] < self.bits,
                        "lane leading-one exceeds the declared width"
                    );
                    let s = truncate_fraction(xm[i], na[i], h)
                        + truncate_fraction(ym[i], nb[i], h);
                    let mut term = lin_term(s, h, lin_shift);
                    if m > 0 {
                        term += params.c_fixed[params.segment(s)];
                    }
                    debug_assert!(term >= 0, "compensated term left the nonnegative mantissa range");
                    *r_i = narrow_result((term as u128) << (na[i] + nb[i]), F) * keep[i];
                }
                r
            },
            |ta, tb, tout| self.mul_batch(ta, tb, tout),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 7: 8-bit scaleTRIM(3,4) with the paper's Table-7 constants,
    /// A=48, B=81 → exactly 4070 (exact product 3888). This pins the whole
    /// fixed-point datapath bit-for-bit against the paper's worked example.
    #[test]
    fn fig7_worked_example_paper_constants() {
        let params = crate::lut::paper_table7_params(3, 4).unwrap();
        let m = ScaleTrim::with_params(8, params);
        let approx = m.mul(48, 81);
        assert_eq!(
            approx, 4070,
            "Fig. 7 expects 4070 (got {approx}); exact is {}",
            48 * 81
        );
    }

    /// Same example with our own calibration: must stay in the same
    /// neighbourhood (the constants differ slightly; see EXPERIMENTS.md).
    #[test]
    fn fig7_with_own_calibration_close() {
        let m = ScaleTrim::new(8, 3, 4);
        let approx = m.mul(48, 81);
        assert!(
            (3950..=4150).contains(&approx),
            "48*81 ~ 4070 expected, got {approx}"
        );
    }

    /// The shift-underflow guard is enforced on the external-constants
    /// path too: `(F − h + ΔEE) as u32` would wrap for ΔEE < h − F.
    #[test]
    #[should_panic(expected = "linearization shift")]
    fn with_params_rejects_underflowing_shift() {
        let mut params = crate::lut::paper_table7_params(3, 4).unwrap();
        params.delta_ee = -20; // 16 − 3 − 20 < 0
        let _ = ScaleTrim::with_params(8, params);
    }

    #[test]
    fn zero_bypass() {
        let m = ScaleTrim::new(8, 3, 4);
        for v in 0..256u64 {
            assert_eq!(m.mul(0, v), 0);
            assert_eq!(m.mul(v, 0), 0);
        }
    }

    #[test]
    fn commutative_by_construction() {
        let m = ScaleTrim::new(8, 4, 8);
        for a in 1..256u64 {
            for b in a..256u64 {
                assert_eq!(m.mul(a, b), m.mul(b, a));
            }
        }
    }

    #[test]
    fn powers_of_two_near_exact_without_compensation() {
        // X = Y = 0 -> approx = 2^(na+nb) exactly for M=0.
        let m = ScaleTrim::new(8, 3, 0);
        for i in 0..8 {
            for j in 0..8 {
                let (a, b) = (1u64 << i, 1u64 << j);
                assert_eq!(m.mul(a, b), a * b, "2^{i} * 2^{j}");
            }
        }
    }

    #[test]
    fn result_fits_double_width() {
        let m = ScaleTrim::new(8, 5, 8);
        for a in 1..256u64 {
            for b in 1..256u64 {
                let p = m.mul(a, b);
                // bounded by 2^(na+nb) * (1 + ~2 + C) < 4 * 2^14 = 2^16 * ...
                assert!(p < 1 << 18, "a={a} b={b} p={p}");
            }
        }
    }

    #[test]
    fn mred_improves_with_h_and_m() {
        // Coarse monotonicity on the full 8-bit space: accuracy should
        // improve (MRED drop) with larger h, and with M at fixed h.
        let mred = |h: u32, m: u32| -> f64 {
            let mult = ScaleTrim::new(8, h, m);
            let mut sum = 0.0;
            let mut n = 0u64;
            for a in 1..256u64 {
                for b in 1..256u64 {
                    let exact = (a * b) as f64;
                    sum += ((mult.mul(a, b) as f64 - exact) / exact).abs();
                    n += 1;
                }
            }
            100.0 * sum / n as f64
        };
        let m34 = mred(3, 4);
        let m30 = mred(3, 0);
        let m54 = mred(5, 4);
        assert!(m34 < m30, "compensation should help: {m34} !< {m30}");
        assert!(m54 < m34, "larger h should help: {m54} !< {m34}");
    }

    /// Paper Table 4 anchors. For h=3 our calibration matches the paper's
    /// reported MRED within 0.2 pp; for h ≥ 4 our constants are strictly
    /// *better* than the paper's reported numbers (see EXPERIMENTS.md), so
    /// the assertion is match-or-beat with a small matching slack.
    #[test]
    fn table4_mred_anchors() {
        let anchors = [
            (3u32, 0u32, 5.75f64),
            (3, 4, 3.73),
            (3, 8, 3.53),
            (4, 8, 3.34),
            (5, 8, 2.12),
        ];
        for (h, m, paper) in anchors {
            let mult = ScaleTrim::new(8, h, m);
            let mut sum = 0.0;
            let mut n = 0u64;
            for a in 1..256u64 {
                for b in 1..256u64 {
                    let exact = (a * b) as f64;
                    sum += ((mult.mul(a, b) as f64 - exact) / exact).abs();
                    n += 1;
                }
            }
            let mred = 100.0 * sum / n as f64;
            assert!(
                mred <= paper + 0.35,
                "scaleTRIM({h},{m}): MRED {mred:.2} should be <= paper {paper} (+slack)"
            );
        }
    }
}
