//! `DesignSpec` — the typed identity of every configuration in the zoo.
//!
//! The paper's central object is a *family* of multipliers parameterised by
//! truncation width `h` and segment count `M`; this module makes that family
//! first-class. A `DesignSpec` is a plain-data enum with one variant per
//! design family, and it is the single source of truth for configuration
//! identity across the system:
//!
//! - [`Display`](std::fmt::Display) renders the exact paper label
//!   (`scaleTRIM(3,4)`, `TOSAM(1,5)`, `MBM-2`, …);
//! - [`FromStr`](std::str::FromStr) parses a label back — the round trip is
//!   lossless, and a failed parse yields a [`ParseSpecError`] that names the
//!   nearest registered labels instead of a silent `None`;
//! - [`DesignSpec::build`] constructs the behavioural model in O(1) without
//!   materialising the zoo;
//! - [`DesignSpec::enumerate`] regenerates the paper's 8- and 16-bit
//!   registries from data tables;
//! - [`DesignSpec::to_json`] / [`DesignSpec::from_json`] make specs wire-
//!   and artifact-safe through [`crate::util::json`].
//!
//! Three families pin their operand width inside the label itself
//! (`Exact8`, `AXM8-4`, `SCDM8-4`); their variants carry `bits` so the
//! label round-trips, and [`DesignSpec::build`] rejects a mismatched width
//! with a typed error.

use super::{
    ApproxMultiplier, Axm, Drum, Dsm, EvoLibSurrogate, Exact, Ilm, Letam, Mbm, Mitchell,
    MitchellLodII, Msamz, PiecewiseLinear, Roba, ScaleTrim, Scdm, Tosam,
};
use crate::util::json::Json;
use std::fmt;
use std::str::FromStr;

/// Typed identity of one zoo configuration: family + parameters.
///
/// `Display` renders the paper label, `FromStr` parses it back (lossless),
/// and [`DesignSpec::build`] turns the spec into a behavioural model at a
/// given operand width. Equality/hashing over specs replaces every string
/// comparison the system used to do (LUT cache keys, coordinator lanes,
/// hardware-model dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DesignSpec {
    /// scaleTRIM(h, M) — this paper (truncation + linearization + LUT
    /// compensation); `m == 0` disables compensation.
    ScaleTrim {
        /// Truncation width `h` (≥ 2; the ΔEE fit needs α < 2).
        h: u32,
        /// Compensation segment count `M` (0 or a power of two).
        m: u32,
    },
    /// scaleTRIM-Q(h, M) — the quantile-segmented variant: same datapath
    /// as scaleTRIM, but the compensation segment boundaries are placed at
    /// error-mass quantiles of the truncated-sum space instead of the
    /// paper's uniform split (selected by `M − 1` threshold comparators
    /// rather than MSB indexing). Calibrated by
    /// [`CalibStrategy::Quantile`](crate::calib::CalibStrategy).
    ScaleTrimQ {
        /// Truncation width `h` (≥ 2, like scaleTRIM).
        h: u32,
        /// Compensation segment count `M` (≥ 2; any integer — no
        /// power-of-two constraint, the comparators don't care).
        m: u32,
    },
    /// TOSAM(t, h) — truncation + rounding (Vahdat'19); the evaluated
    /// family has `t < h`.
    Tosam {
        /// Rounded multiplier-part width `t`.
        t: u32,
        /// Truncated adder-part width `h`.
        h: u32,
    },
    /// DRUM(m) — dynamic-range unbiased truncation (Hashemi'15).
    Drum {
        /// Kept dynamic range `m` (≥ 2).
        m: u32,
    },
    /// DSM(m) — static segment method (Narayanamoorthy'15).
    Dsm {
        /// Segment width `m` (≥ 2).
        m: u32,
    },
    /// Mitchell'62 logarithmic multiplier.
    Mitchell,
    /// MBM-k — minimally-biased Mitchell (Saadat'18).
    Mbm {
        /// Truncation level `k` (≥ 1).
        k: u32,
    },
    /// ILM-k — improved (nearest-one) logarithmic multiplier (Ansari'21).
    Ilm {
        /// Operand-truncation level `k` (0 = untruncated).
        k: u32,
    },
    /// Mitchell with approximate leading-one detector (Ansari'21).
    LodII {
        /// LOD approximation level `j`.
        j: u32,
    },
    /// AXM — recursive approximate MAC (Deepsita'23). Width-pinned: the
    /// label embeds the operand width (e.g. `AXM8-4`).
    Axm {
        /// Operand width baked into the design point.
        bits: u32,
        /// Accuracy level `k` (3 or 4).
        k: u32,
    },
    /// SCDM — carry-disregard array multiplier (Shakibhamedan'24).
    /// Width-pinned like AXM (e.g. `SCDM8-4`).
    Scdm {
        /// Operand width baked into the design point.
        bits: u32,
        /// Number of carry-free low columns `k` (< 2·bits).
        k: u32,
    },
    /// MSAMZ(k, m) — MSB-guided shift-add multiplier (Huang'24).
    Msamz {
        /// Correction-adder width `k`.
        k: u32,
        /// Kept MSB width `m` (≥ 1).
        m: u32,
    },
    /// Piecewise(h=…,S=…) — piecewise linearization (Sec. IV-D ablation).
    Piecewise {
        /// Truncation width `h` (≥ 1).
        h: u32,
        /// Segment count `S` (≥ 1).
        s: u32,
    },
    /// EVO-lib-k — broken-array surrogates (Mrazek'17), k ∈ 1..=4.
    EvoLib {
        /// Library point `k` (1..=4).
        k: u32,
    },
    /// LETAM(t) — truncation multiplier (Vahdat'17).
    Letam {
        /// Kept width `t` (≥ 2).
        t: u32,
    },
    /// RoBA — rounding to powers of two (Zendegani'17).
    Roba,
    /// Exact array multiplier baseline. Width-pinned: the label embeds the
    /// operand width (e.g. `Exact8`).
    Exact {
        /// Operand width baked into the design point (2..=32).
        bits: u32,
    },
}

/// Parse failure for a configuration label: the offending input, the
/// reason, and the nearest registered labels (edit distance over both
/// zoos), so an `--config` typo points at the fix instead of a bare
/// "unknown config".
#[derive(Debug, Clone)]
pub struct ParseSpecError {
    /// The label that failed to parse.
    pub input: String,
    /// Human-readable reason (wrong arity, out-of-range parameter, …).
    pub reason: String,
    /// Closest registered labels, best first (may be empty).
    pub suggestions: Vec<String>,
}

impl ParseSpecError {
    fn new(input: &str, reason: String) -> Self {
        Self {
            suggestions: nearest_labels(input, 3),
            input: input.to_string(),
            reason,
        }
    }
}

impl fmt::Display for ParseSpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown config {:?}: {}", self.input, self.reason)?;
        if !self.suggestions.is_empty() {
            write!(f, " (nearest registered: {})", self.suggestions.join(", "))?;
        }
        Ok(())
    }
}

impl std::error::Error for ParseSpecError {}

impl fmt::Display for DesignSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DesignSpec::ScaleTrim { h, m } => write!(f, "scaleTRIM({h},{m})"),
            DesignSpec::ScaleTrimQ { h, m } => write!(f, "scaleTRIM-Q({h},{m})"),
            DesignSpec::Tosam { t, h } => write!(f, "TOSAM({t},{h})"),
            DesignSpec::Drum { m } => write!(f, "DRUM({m})"),
            DesignSpec::Dsm { m } => write!(f, "DSM({m})"),
            DesignSpec::Mitchell => write!(f, "Mitchell"),
            DesignSpec::Mbm { k } => write!(f, "MBM-{k}"),
            DesignSpec::Ilm { k } => write!(f, "ILM{k}"),
            DesignSpec::LodII { j } => write!(f, "Mitchell_LODII_{j}"),
            DesignSpec::Axm { bits, k } => write!(f, "AXM{bits}-{k}"),
            DesignSpec::Scdm { bits, k } => write!(f, "SCDM{bits}-{k}"),
            DesignSpec::Msamz { k, m } => write!(f, "MSAMZ({k},{m})"),
            DesignSpec::Piecewise { h, s } => write!(f, "Piecewise(h={h},S={s})"),
            DesignSpec::EvoLib { k } => write!(f, "EVO-lib{k}"),
            DesignSpec::Letam { t } => write!(f, "LETAM({t})"),
            DesignSpec::Roba => write!(f, "RoBA"),
            DesignSpec::Exact { bits } => write!(f, "Exact{bits}"),
        }
    }
}

/// Ceiling on any spec parameter (enforced by `validate_params`, hence by
/// the label grammar, JSON deserialisation and `build` alike). Every
/// family parameter is a bit-width, shift amount or segment count —
/// nothing legitimate exceeds this, and capping keeps later width
/// arithmetic (`2·bits`, `m + k`) overflow-free by construction.
const PARAM_MAX: u32 = 64;

fn check_param(family: &str, v: u32) -> Result<u32, String> {
    if v > PARAM_MAX {
        Err(format!("{family}: parameter {v} out of range (max {PARAM_MAX})"))
    } else {
        Ok(v)
    }
}

/// Split a `"(a,b)"` suffix into exactly two raw comma-separated parts.
fn two_parts<'a>(family: &str, rest: &'a str) -> Result<(&'a str, &'a str), String> {
    let inner = rest
        .strip_prefix('(')
        .and_then(|r| r.strip_suffix(')'))
        .ok_or_else(|| format!("{family} takes \"(a,b)\" after the family name, got {rest:?}"))?;
    let parts: Vec<&str> = inner.split(',').collect();
    if parts.len() != 2 {
        return Err(format!(
            "{family} takes exactly two comma-separated parameters, got {} in {rest:?}",
            parts.len()
        ));
    }
    Ok((parts[0].trim(), parts[1].trim()))
}

fn int_param(family: &str, p: &str) -> Result<u32, String> {
    p.parse()
        .map_err(|_| format!("{family}: {p:?} is not an integer parameter"))
}

/// Split a bare `"(a,b)"` suffix into exactly two `u32`s.
fn two_args(family: &str, rest: &str) -> Result<(u32, u32), String> {
    let (a, b) = two_parts(family, rest)?;
    Ok((int_param(family, a)?, int_param(family, b)?))
}

/// Split a keyed `"(k1N,k2M)"` suffix (e.g. `Piecewise(h=4,S=4)`): each
/// key must appear on its own position — `Piecewise(S=2,h=8)` is a typed
/// error, not a silent transposition.
fn two_args_keyed(
    family: &str,
    rest: &str,
    k1: &str,
    k2: &str,
) -> Result<(u32, u32), String> {
    let (a, b) = two_parts(family, rest)?;
    let a = a
        .strip_prefix(k1)
        .ok_or_else(|| format!("{family}: first parameter must be \"{k1}<int>\", got {a:?}"))?;
    let b = b
        .strip_prefix(k2)
        .ok_or_else(|| format!("{family}: second parameter must be \"{k2}<int>\", got {b:?}"))?;
    Ok((int_param(family, a)?, int_param(family, b)?))
}

/// Split a `"(a)"` suffix into one `u32`.
fn one_arg(family: &str, rest: &str) -> Result<u32, String> {
    let inner = rest
        .strip_prefix('(')
        .and_then(|r| r.strip_suffix(')'))
        .ok_or_else(|| format!("{family} takes \"(m)\" after the family name, got {rest:?}"))?;
    inner
        .trim()
        .parse()
        .map_err(|_| format!("{family}: {:?} is not an integer parameter", inner.trim()))
}

/// Split a `"{bits}-{k}"` body (the width-pinned AXM/SCDM label form).
fn bits_dash_k(family: &str, rest: &str) -> Result<(u32, u32), String> {
    let (b, k) = rest
        .split_once('-')
        .ok_or_else(|| format!("{family} labels look like \"{family}<bits>-<k>\", got {rest:?}"))?;
    let bits: u32 = b
        .parse()
        .map_err(|_| format!("{family}: width {b:?} is not an integer"))?;
    let k: u32 = k
        .parse()
        .map_err(|_| format!("{family}: level {k:?} is not an integer"))?;
    Ok((bits, k))
}

impl FromStr for DesignSpec {
    type Err = ParseSpecError;

    /// Parse a paper label back into its spec. The grammar is exactly what
    /// [`Display`](std::fmt::Display) emits; family-intrinsic parameter
    /// rules (those that do not depend on the operand width) are enforced
    /// here, width-dependent rules in [`DesignSpec::build`].
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        parse_label(s).map_err(|reason| ParseSpecError::new(s, reason))
    }
}

fn parse_label(s: &str) -> Result<DesignSpec, String> {
    let spec = parse_syntax(s)?;
    spec.validate_params()?;
    Ok(spec)
}

/// Label shape → spec, no parameter-rule checks (those live in
/// [`DesignSpec::validate_params`], shared with `build` and `from_json`).
fn parse_syntax(s: &str) -> Result<DesignSpec, String> {
    if s.is_empty() {
        return Err("empty label".into());
    }
    // Longest-prefix families first (Mitchell_LODII_ before Mitchell).
    if let Some(j) = s.strip_prefix("Mitchell_LODII_") {
        let j: u32 = j
            .parse()
            .map_err(|_| format!("Mitchell_LODII level {j:?} is not an integer"))?;
        return Ok(DesignSpec::LodII { j });
    }
    if s == "Mitchell" {
        return Ok(DesignSpec::Mitchell);
    }
    if s == "RoBA" {
        return Ok(DesignSpec::Roba);
    }
    // scaleTRIM-Q before scaleTRIM (longest-prefix, like Mitchell_LODII_).
    if let Some(rest) = s.strip_prefix("scaleTRIM-Q") {
        let (h, m) = two_args("scaleTRIM-Q", rest)?;
        return Ok(DesignSpec::ScaleTrimQ { h, m });
    }
    if let Some(rest) = s.strip_prefix("scaleTRIM") {
        let (h, m) = two_args("scaleTRIM", rest)?;
        return Ok(DesignSpec::ScaleTrim { h, m });
    }
    if let Some(rest) = s.strip_prefix("TOSAM") {
        let (t, h) = two_args("TOSAM", rest)?;
        return Ok(DesignSpec::Tosam { t, h });
    }
    if let Some(rest) = s.strip_prefix("DRUM") {
        return Ok(DesignSpec::Drum { m: one_arg("DRUM", rest)? });
    }
    if let Some(rest) = s.strip_prefix("DSM") {
        return Ok(DesignSpec::Dsm { m: one_arg("DSM", rest)? });
    }
    if let Some(k) = s.strip_prefix("MBM-") {
        let k: u32 = k
            .parse()
            .map_err(|_| format!("MBM level {k:?} is not an integer"))?;
        return Ok(DesignSpec::Mbm { k });
    }
    if let Some(k) = s.strip_prefix("ILM") {
        let k: u32 = k
            .parse()
            .map_err(|_| format!("ILM level {k:?} is not an integer"))?;
        return Ok(DesignSpec::Ilm { k });
    }
    if let Some(rest) = s.strip_prefix("AXM") {
        let (bits, k) = bits_dash_k("AXM", rest)?;
        return Ok(DesignSpec::Axm { bits, k });
    }
    if let Some(rest) = s.strip_prefix("SCDM") {
        let (bits, k) = bits_dash_k("SCDM", rest)?;
        return Ok(DesignSpec::Scdm { bits, k });
    }
    if let Some(rest) = s.strip_prefix("MSAMZ") {
        let (k, m) = two_args("MSAMZ", rest)?;
        return Ok(DesignSpec::Msamz { k, m });
    }
    if let Some(rest) = s.strip_prefix("Piecewise") {
        let (h, seg) = two_args_keyed("Piecewise", rest, "h=", "S=")?;
        return Ok(DesignSpec::Piecewise { h, s: seg });
    }
    if let Some(k) = s.strip_prefix("EVO-lib") {
        let k: u32 = k
            .parse()
            .map_err(|_| format!("EVO-lib point {k:?} is not an integer"))?;
        return Ok(DesignSpec::EvoLib { k });
    }
    if let Some(rest) = s.strip_prefix("LETAM") {
        return Ok(DesignSpec::Letam { t: one_arg("LETAM", rest)? });
    }
    if let Some(b) = s.strip_prefix("Exact") {
        if b.is_empty() {
            return Err("Exact labels carry the width, e.g. \"Exact8\"".into());
        }
        let bits: u32 = b
            .parse()
            .map_err(|_| format!("Exact width {b:?} is not an integer"))?;
        return Ok(DesignSpec::Exact { bits });
    }
    Err("no design family with this name".into())
}

impl DesignSpec {
    /// Family-intrinsic parameter rules — the width-independent half of
    /// validity, shared by the label grammar, [`DesignSpec::from_json`]
    /// and [`DesignSpec::build`] (the fields are plain data, so specs can
    /// arrive unvalidated through direct construction). Width-dependent
    /// rules live in [`DesignSpec::validate_for`].
    fn validate_params(&self) -> Result<(), String> {
        use DesignSpec::*;
        // Cap every parameter first so later width arithmetic (`2·bits`,
        // `m + k`) cannot overflow. Every variant carries at most two
        // numeric fields; 0 pads the unused slot.
        let (p1, p2) = match *self {
            ScaleTrim { h, m } | ScaleTrimQ { h, m } => (h, m),
            Tosam { t, h } => (t, h),
            Drum { m } | Dsm { m } => (m, 0),
            Mbm { k } | Ilm { k } | EvoLib { k } => (k, 0),
            LodII { j } => (j, 0),
            Axm { bits, k } | Scdm { bits, k } => (bits, k),
            Msamz { k, m } => (k, m),
            Piecewise { h, s } => (h, s),
            Letam { t } => (t, 0),
            Exact { bits } => (bits, 0),
            Mitchell | Roba => (0, 0),
        };
        check_param(self.family(), p1)?;
        check_param(self.family(), p2)?;
        match *self {
            ScaleTrim { h, m } => {
                if h < 2 {
                    return Err(format!(
                        "scaleTRIM h must be >= 2 (the ΔEE fit needs α < 2), got {h}"
                    ));
                }
                if h > 12 {
                    return Err(format!("scaleTRIM h must be <= 12 (calibration cap), got {h}"));
                }
                if m != 0 && !m.is_power_of_two() {
                    return Err(format!("scaleTRIM M must be 0 or a power of two, got {m}"));
                }
            }
            ScaleTrimQ { h, m } => {
                if h < 2 {
                    return Err(format!(
                        "scaleTRIM-Q h must be >= 2 (the ΔEE fit needs α < 2), got {h}"
                    ));
                }
                if h > 12 {
                    return Err(format!(
                        "scaleTRIM-Q h must be <= 12 (calibration cap), got {h}"
                    ));
                }
                if m < 2 {
                    return Err(format!(
                        "scaleTRIM-Q M must be >= 2 (quantile segmentation needs at least \
                         two segments; use scaleTRIM(h,0) for no compensation), got {m}"
                    ));
                }
            }
            Tosam { t, h } => {
                if h < 1 {
                    return Err("TOSAM h must be >= 1".into());
                }
                if t >= h {
                    return Err(format!(
                        "TOSAM(t,h) requires t < h (the paper evaluates t ∈ 0..=3, h ∈ 2..=7), got t={t} h={h}"
                    ));
                }
            }
            Drum { m } => {
                if m < 2 {
                    return Err(format!("DRUM m must be >= 2, got {m}"));
                }
            }
            Dsm { m } => {
                if m < 2 {
                    return Err(format!("DSM m must be >= 2, got {m}"));
                }
            }
            Mbm { k } => {
                if k < 1 {
                    return Err("MBM k must be >= 1".into());
                }
            }
            Axm { bits, k } => {
                if !(bits.is_power_of_two() && bits >= 4) {
                    return Err(format!("AXM width must be a power of two >= 4, got {bits}"));
                }
                if !(k == 3 || k == 4) {
                    return Err(format!("AXM accuracy level must be 3 or 4, got {k}"));
                }
            }
            Scdm { bits, k } => {
                if bits < 2 {
                    return Err(format!("SCDM width must be >= 2, got {bits}"));
                }
                if k >= 2 * bits {
                    return Err(format!("SCDM k must be < 2·bits = {}, got {k}", 2 * bits));
                }
            }
            Msamz { m, .. } => {
                if m < 1 {
                    return Err("MSAMZ m must be >= 1".into());
                }
            }
            Piecewise { h, s } => {
                if h < 1 || s < 1 {
                    return Err(format!("Piecewise needs h >= 1 and S >= 1, got h={h} S={s}"));
                }
            }
            EvoLib { k } => {
                if !(1..=4).contains(&k) {
                    return Err(format!("EVO-lib points are 1..=4, got {k}"));
                }
            }
            Letam { t } => {
                if t < 2 {
                    return Err(format!("LETAM t must be >= 2, got {t}"));
                }
            }
            Exact { bits } => {
                if !(2..=32).contains(&bits) {
                    return Err(format!("Exact width must be in 2..=32, got {bits}"));
                }
            }
            Mitchell | Ilm { .. } | LodII { .. } | Roba => {}
        }
        Ok(())
    }

    /// Width-dependent validity check: does this spec describe a buildable
    /// configuration at operand width `bits`? Mirrors (and fronts) every
    /// constructor assertion so [`DesignSpec::build`] returns a typed error
    /// instead of panicking.
    pub fn validate_for(&self, bits: u32) -> crate::Result<()> {
        use DesignSpec::*;
        anyhow::ensure!((2..=32).contains(&bits), "operand width must be in 2..=32, got {bits}");
        match *self {
            ScaleTrim { h, .. } | ScaleTrimQ { h, .. } => {
                anyhow::ensure!(
                    (4..=24).contains(&bits),
                    "{self} supports widths 4..=24, got {bits}"
                );
                anyhow::ensure!(h < bits, "{self} needs h < bits, got h={h} at {bits} bits");
            }
            Tosam { h, .. } => {
                anyhow::ensure!(h < bits, "{self} needs h < bits, got h={h} at {bits} bits");
            }
            Drum { m } => {
                anyhow::ensure!(m <= bits, "{self} needs m <= bits, got m={m} at {bits} bits");
            }
            Dsm { m } => {
                anyhow::ensure!(m < bits, "{self} needs m < bits, got m={m} at {bits} bits");
            }
            Mbm { k } => {
                anyhow::ensure!(k < bits, "{self} needs k < bits, got k={k} at {bits} bits");
            }
            Letam { t } => {
                anyhow::ensure!(t <= bits, "{self} needs t <= bits, got t={t} at {bits} bits");
            }
            Piecewise { h, .. } => {
                anyhow::ensure!(h < bits, "{self} needs h < bits, got h={h} at {bits} bits");
            }
            Msamz { k, m } => {
                // checked: specs are plain data, so `m`/`k` can arrive
                // unvalidated through direct construction.
                anyhow::ensure!(
                    m.checked_add(k).is_some_and(|s| s <= 2 * bits),
                    "{self} needs m + k <= 2·bits, got {m}+{k} at {bits} bits"
                );
            }
            Axm { bits: b, .. } | Scdm { bits: b, .. } | Exact { bits: b } => {
                anyhow::ensure!(
                    b == bits,
                    "wrong width: {self} is pinned to {b}-bit operands, cannot build at {bits} bits"
                );
            }
            Mitchell | Ilm { .. } | LodII { .. } | EvoLib { .. } | Roba => {}
        }
        Ok(())
    }

    /// The full validity check at a width: family-intrinsic parameter
    /// rules plus the width-dependent rules of
    /// [`DesignSpec::validate_for`]. This is the *single* typed error path
    /// shared by [`DesignSpec::build`] and the direct constructors
    /// (`ScaleTrim::try_new`, `PiecewiseLinear::try_new`, …) — direct
    /// construction and spec-driven construction can no longer disagree
    /// about what is a valid configuration.
    pub fn validate(&self, bits: u32) -> crate::Result<()> {
        self.validate_params()
            .map_err(|e| anyhow::anyhow!("invalid spec {self}: {e}"))?;
        self.validate_for(bits)
    }

    /// Construct the behavioural model for this spec at operand width
    /// `bits` — O(1), no zoo materialisation. Returns a typed error when
    /// the spec is invalid at this width (see [`DesignSpec::validate`])
    /// or carries intrinsically invalid parameters (possible through
    /// direct construction — the fields are plain data), so it never
    /// panics inside a constructor assertion.
    pub fn build(&self, bits: u32) -> crate::Result<Box<dyn ApproxMultiplier>> {
        self.validate(bits)?;
        use DesignSpec::*;
        Ok(match *self {
            ScaleTrim { h, m } => Box::new(self::ScaleTrim::new(bits, h, m)),
            ScaleTrimQ { h, m } => Box::new(self::ScaleTrim::with_strategy(
                bits,
                h,
                m,
                crate::calib::CalibStrategy::Quantile,
            )?),
            Tosam { t, h } => Box::new(self::Tosam::new(bits, t, h)),
            Drum { m } => Box::new(self::Drum::new(bits, m)),
            Dsm { m } => Box::new(self::Dsm::new(bits, m)),
            Mitchell => Box::new(self::Mitchell::new(bits)),
            Mbm { k } => Box::new(self::Mbm::new(bits, k)),
            Ilm { k } => Box::new(self::Ilm::new(bits, k)),
            LodII { j } => Box::new(MitchellLodII::new(bits, j)),
            Axm { bits: b, k } => Box::new(self::Axm::new(b, k)),
            Scdm { bits: b, k } => Box::new(self::Scdm::new(b, k)),
            Msamz { k, m } => Box::new(self::Msamz::new(bits, k, m)),
            Piecewise { h, s } => Box::new(PiecewiseLinear::new(bits, h, s)),
            EvoLib { k } => Box::new(EvoLibSurrogate::new(bits, k)),
            Letam { t } => Box::new(self::Letam::new(bits, t)),
            Roba => Box::new(self::Roba::new(bits)),
            Exact { bits: b } => Box::new(self::Exact::new(b)),
        })
    }

    /// The paper's registered configurations at a given width, in paper
    /// order — the data tables behind `paper_configs_8bit` (Fig. 9 /
    /// Table 4) and `paper_configs_16bit` (Fig. 10). Widths other than 8
    /// and 16 are a typed error, not an empty list.
    pub fn enumerate(bits: u32) -> crate::Result<Vec<DesignSpec>> {
        use DesignSpec::*;
        match bits {
            8 => {
                let mut v = Vec::new();
                for k in 1..=5 {
                    v.push(Mbm { k });
                }
                v.push(Mitchell);
                for m in 3..=7 {
                    v.push(Dsm { m });
                }
                for m in 3..=7 {
                    v.push(Drum { m });
                }
                for (t, h) in TOSAM_8BIT {
                    v.push(Tosam { t, h });
                }
                for h in 2..=7 {
                    for m in [0, 4, 8] {
                        v.push(ScaleTrim { h, m });
                    }
                }
                for k in 1..=4 {
                    v.push(EvoLib { k });
                }
                v.push(Ilm { k: 0 });
                v.push(Ilm { k: 5 });
                v.push(Axm { bits: 8, k: 4 });
                v.push(Axm { bits: 8, k: 3 });
                v.push(LodII { j: 0 });
                v.push(LodII { j: 4 });
                v.push(Scdm { bits: 8, k: 4 });
                v.push(Scdm { bits: 8, k: 6 });
                v.push(Msamz { k: 4, m: 4 });
                Ok(v)
            }
            16 => {
                let mut v = vec![Mitchell];
                for k in 1..=4 {
                    v.push(Mbm { k });
                }
                for m in 3..=8 {
                    v.push(Drum { m });
                }
                for m in 4..=8 {
                    v.push(Dsm { m });
                }
                for (t, h) in TOSAM_16BIT {
                    v.push(Tosam { t, h });
                }
                for h in 3..=8 {
                    for m in [0, 4, 8] {
                        v.push(ScaleTrim { h, m });
                    }
                }
                Ok(v)
            }
            other => anyhow::bail!("no registered zoo at {other} bits (supported: 8, 16)"),
        }
    }

    /// Serialise to a JSON object (`{"family":"scaleTRIM","h":3,"m":4}`):
    /// self-describing field names per family, width-pinned families carry
    /// `bits`. Round-trips through [`DesignSpec::from_json`].
    pub fn to_json(&self) -> Json {
        use DesignSpec::*;
        let o = Json::obj().set("family", self.family());
        match *self {
            ScaleTrim { h, m } | ScaleTrimQ { h, m } => o.set("h", h).set("m", m),
            Tosam { t, h } => o.set("t", t).set("h", h),
            Drum { m } | Dsm { m } => o.set("m", m),
            Mbm { k } | Ilm { k } | EvoLib { k } => o.set("k", k),
            LodII { j } => o.set("j", j),
            Axm { bits, k } | Scdm { bits, k } => o.set("bits", bits).set("k", k),
            Msamz { k, m } => o.set("k", k).set("m", m),
            Piecewise { h, s } => o.set("h", h).set("s", s),
            Letam { t } => o.set("t", t),
            Exact { bits } => o.set("bits", bits),
            Mitchell | Roba => o,
        }
    }

    /// Deserialise from the [`DesignSpec::to_json`] object form. The
    /// reconstructed spec passes through the same parameter rules as the
    /// label grammar, so a JSON document can never smuggle in parameters
    /// `FromStr` would reject.
    pub fn from_json(v: &Json) -> crate::Result<DesignSpec> {
        let Json::Obj(fields) = v else {
            anyhow::bail!("DesignSpec JSON must be an object, got {}", v.to_string());
        };
        let get = |key: &str| -> crate::Result<u32> {
            match fields.iter().find(|(k, _)| k == key).map(|(_, v)| v) {
                Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 && *x <= u32::MAX as f64 => {
                    Ok(*x as u32)
                }
                Some(other) => anyhow::bail!(
                    "DesignSpec field {key:?} must be a non-negative integer, got {}",
                    other.to_string()
                ),
                None => anyhow::bail!("DesignSpec JSON missing field {key:?}"),
            }
        };
        let family = match fields.iter().find(|(k, _)| k == "family").map(|(_, v)| v) {
            Some(Json::Str(s)) => s.as_str(),
            _ => anyhow::bail!("DesignSpec JSON missing string field \"family\""),
        };
        use DesignSpec::*;
        let spec = match family {
            "scaleTRIM" => ScaleTrim { h: get("h")?, m: get("m")? },
            "scaleTRIM-Q" => ScaleTrimQ { h: get("h")?, m: get("m")? },
            "TOSAM" => Tosam { t: get("t")?, h: get("h")? },
            "DRUM" => Drum { m: get("m")? },
            "DSM" => Dsm { m: get("m")? },
            "Mitchell" => Mitchell,
            "MBM" => Mbm { k: get("k")? },
            "ILM" => Ilm { k: get("k")? },
            "Mitchell_LODII" => LodII { j: get("j")? },
            "AXM" => Axm { bits: get("bits")?, k: get("k")? },
            "SCDM" => Scdm { bits: get("bits")?, k: get("k")? },
            "MSAMZ" => Msamz { k: get("k")?, m: get("m")? },
            "Piecewise" => Piecewise { h: get("h")?, s: get("s")? },
            "EVO-lib" => EvoLib { k: get("k")? },
            "LETAM" => Letam { t: get("t")? },
            "RoBA" => Roba,
            "Exact" => Exact { bits: get("bits")? },
            other => anyhow::bail!("unknown DesignSpec family {other:?}"),
        };
        // Same parameter rules as the label grammar, shared.
        spec.validate_params()
            .map_err(|e| anyhow::anyhow!("invalid DesignSpec parameters in JSON: {e}"))?;
        Ok(spec)
    }

    /// Family tag (the JSON discriminant and the stable grouping key for
    /// reports: every `scaleTRIM(h,M)` shares `"scaleTRIM"`).
    pub fn family(&self) -> &'static str {
        use DesignSpec::*;
        match self {
            ScaleTrim { .. } => "scaleTRIM",
            ScaleTrimQ { .. } => "scaleTRIM-Q",
            Tosam { .. } => "TOSAM",
            Drum { .. } => "DRUM",
            Dsm { .. } => "DSM",
            Mitchell => "Mitchell",
            Mbm { .. } => "MBM",
            Ilm { .. } => "ILM",
            LodII { .. } => "Mitchell_LODII",
            Axm { .. } => "AXM",
            Scdm { .. } => "SCDM",
            Msamz { .. } => "MSAMZ",
            Piecewise { .. } => "Piecewise",
            EvoLib { .. } => "EVO-lib",
            Letam { .. } => "LETAM",
            Roba => "RoBA",
            Exact { .. } => "Exact",
        }
    }
}

/// The paper's 8-bit TOSAM(t, h) points (Fig. 9 / Table 4 order).
const TOSAM_8BIT: [(u32, u32); 17] = [
    (0, 2),
    (1, 2),
    (0, 3),
    (1, 3),
    (2, 3),
    (0, 4),
    (1, 4),
    (2, 4),
    (3, 4),
    (0, 5),
    (1, 5),
    (2, 5),
    (3, 5),
    (0, 6),
    (2, 6),
    (2, 7),
    (3, 7),
];

/// The paper's 16-bit TOSAM(t, h) points (Fig. 10 order).
const TOSAM_16BIT: [(u32, u32); 7] = [(0, 3), (1, 3), (2, 4), (3, 5), (1, 6), (2, 6), (3, 7)];

/// Every label the system registers, for near-miss suggestions: both zoo
/// enumerations plus the standalone baselines that never enter a registry.
fn known_labels() -> Vec<String> {
    let mut labels: Vec<String> = Vec::new();
    for bits in [8u32, 16] {
        if let Ok(zoo) = DesignSpec::enumerate(bits) {
            labels.extend(zoo.iter().map(|s| s.to_string()));
        }
    }
    labels.push("Exact8".into());
    labels.push("Exact16".into());
    labels.push("RoBA".into());
    labels.push("LETAM(4)".into());
    labels.push("Piecewise(h=4,S=4)".into());
    labels.push("scaleTRIM-Q(4,8)".into());
    labels.sort();
    labels.dedup();
    labels
}

/// Classic Levenshtein edit distance (labels are short; O(a·b) is fine).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The `n` registered labels closest to `input` (case-insensitive edit
/// distance, ties broken lexicographically), capped at a distance that
/// still plausibly means "typo".
fn nearest_labels(input: &str, n: usize) -> Vec<String> {
    let needle = input.to_ascii_lowercase();
    let mut scored: Vec<(usize, String)> = known_labels()
        .into_iter()
        .map(|l| (edit_distance(&needle, &l.to_ascii_lowercase()), l))
        .collect();
    scored.sort();
    let cap = (input.len() / 2).max(3);
    scored
        .into_iter()
        .take_while(|(d, _)| *d <= cap)
        .take(n)
        .map(|(_, l)| l)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_labels() {
        assert_eq!(DesignSpec::ScaleTrim { h: 3, m: 4 }.to_string(), "scaleTRIM(3,4)");
        assert_eq!(DesignSpec::Tosam { t: 1, h: 5 }.to_string(), "TOSAM(1,5)");
        assert_eq!(DesignSpec::Mbm { k: 2 }.to_string(), "MBM-2");
        assert_eq!(DesignSpec::LodII { j: 0 }.to_string(), "Mitchell_LODII_0");
        assert_eq!(DesignSpec::Axm { bits: 8, k: 4 }.to_string(), "AXM8-4");
        assert_eq!(DesignSpec::Exact { bits: 8 }.to_string(), "Exact8");
        assert_eq!(
            DesignSpec::Piecewise { h: 4, s: 4 }.to_string(),
            "Piecewise(h=4,S=4)"
        );
        assert_eq!(
            DesignSpec::ScaleTrimQ { h: 4, m: 8 }.to_string(),
            "scaleTRIM-Q(4,8)"
        );
    }

    #[test]
    fn scaletrim_q_round_trips_and_builds() {
        for label in ["scaleTRIM-Q(3,4)", "scaleTRIM-Q(4,8)", "scaleTRIM-Q(4,6)"] {
            let spec: DesignSpec = label.parse().unwrap();
            assert!(matches!(spec, DesignSpec::ScaleTrimQ { .. }), "{label}");
            assert_eq!(spec.to_string(), label);
            let wire = spec.to_json().to_string();
            assert_eq!(DesignSpec::from_json(&Json::parse(&wire).unwrap()).unwrap(), spec);
            let m = spec.build(8).unwrap();
            assert_eq!(m.spec(), spec, "{label}");
            assert_eq!(m.name(), label);
        }
        // The -Q prefix must never be swallowed by the scaleTRIM parser.
        assert_ne!(
            "scaleTRIM-Q(3,4)".parse::<DesignSpec>().unwrap(),
            "scaleTRIM(3,4)".parse::<DesignSpec>().unwrap()
        );
        // Family-intrinsic rules: M >= 2, h >= 2.
        assert!("scaleTRIM-Q(3,1)".parse::<DesignSpec>().is_err());
        assert!("scaleTRIM-Q(1,4)".parse::<DesignSpec>().is_err());
        assert!(DesignSpec::ScaleTrimQ { h: 3, m: 0 }.build(8).is_err());
    }

    #[test]
    fn parse_round_trips_both_zoos() {
        for bits in [8u32, 16] {
            for spec in DesignSpec::enumerate(bits).unwrap() {
                let label = spec.to_string();
                assert_eq!(label.parse::<DesignSpec>().unwrap(), spec, "{label}");
            }
        }
    }

    #[test]
    fn parse_rejects_malformed_labels() {
        for bad in [
            "scaleTRIM(3)",       // wrong arity
            "scaleTRIM(1,4)",     // h < 2
            "scaleTRIM(3,3)",     // M not a power of two
            "TOSAM(9,2)",         // t >= h
            "TOSAM(3)",           // wrong arity
            "DRUM(1)",            // m < 2
            "DRUM(x)",            // not an integer
            "MBM-0",              // k < 1
            "EVO-lib9",           // beyond the library
            "AXM9-4",             // width not a power of two
            "AXM8-5",             // k not in {3,4}
            "SCDM8-16",           // k >= 2·bits
            "Exact",              // width missing
            "Exact1",             // width out of range
            "LETAM(1)",           // t < 2
            "Piecewise(h=0,S=4)", // h < 1
            "Piecewise(S=2,h=8)", // keys transposed — not silently swapped
            "Piecewise(2,8)",     // keys missing entirely
            "TOSAM(h=1,S=5)",     // keyed form on a bare-parameter family
            "DRUM(999)",          // parameter cap
            "warp-drive",         // no such family
            "",                   // empty
        ] {
            assert!(bad.parse::<DesignSpec>().is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn parse_error_suggests_near_misses() {
        let err = "scaleTrim(3,4)".parse::<DesignSpec>().unwrap_err();
        assert!(
            err.suggestions.iter().any(|s| s == "scaleTRIM(3,4)"),
            "suggestions {:?} must contain the case-fixed label",
            err.suggestions
        );
        let msg = err.to_string();
        assert!(msg.contains("scaleTRIM(3,4)"), "{msg}");
    }

    #[test]
    fn build_is_wired_to_every_family() {
        for bits in [8u32, 16] {
            for spec in DesignSpec::enumerate(bits).unwrap() {
                let m = spec.build(bits).unwrap();
                assert_eq!(m.bits(), bits, "{spec}");
                assert_eq!(m.spec(), spec);
                assert_eq!(m.name(), spec.to_string());
            }
        }
        // Standalone baselines outside the registries.
        for (label, bits) in [
            ("RoBA", 8u32),
            ("LETAM(4)", 8),
            ("Piecewise(h=4,S=4)", 8),
            ("Exact8", 8),
            ("Exact16", 16),
        ] {
            let spec: DesignSpec = label.parse().unwrap();
            assert_eq!(spec.build(bits).unwrap().name(), label);
        }
    }

    #[test]
    fn build_rejects_wrong_width() {
        assert!(DesignSpec::Exact { bits: 8 }.build(16).is_err());
        assert!(DesignSpec::Axm { bits: 8, k: 4 }.build(16).is_err());
        assert!(DesignSpec::Scdm { bits: 8, k: 4 }.build(16).is_err());
        // h must stay below the operand width.
        assert!(DesignSpec::ScaleTrim { h: 7, m: 4 }.build(4).is_err());
        assert!(DesignSpec::Tosam { t: 3, h: 9 }.build(8).is_err());
        // And the error is a message, not a panic.
        let e = DesignSpec::Exact { bits: 8 }.build(16).unwrap_err();
        assert!(e.to_string().contains("wrong width"), "{e}");
    }

    /// The fields are plain data, so invalid parameter combinations can be
    /// constructed directly — `build` must reject them as typed errors,
    /// never reach a panicking constructor assertion.
    #[test]
    fn build_rejects_directly_constructed_invalid_specs() {
        assert!(DesignSpec::Tosam { t: 9, h: 2 }.build(8).is_err());
        assert!(DesignSpec::Axm { bits: 6, k: 5 }.build(6).is_err());
        assert!(DesignSpec::EvoLib { k: 9 }.build(8).is_err());
        assert!(DesignSpec::ScaleTrim { h: 1, m: 4 }.build(8).is_err());
        assert!(DesignSpec::Msamz { k: u32::MAX, m: u32::MAX }.build(8).is_err());
        // The error talks about the parameter rule, not "unknown config" —
        // the caller constructed a spec, not a label.
        let e = DesignSpec::Drum { m: 1 }.build(8).unwrap_err().to_string();
        assert!(e.contains("m must be >= 2"), "{e}");
        assert!(!e.contains("unknown config"), "{e}");
    }

    #[test]
    fn enumerate_rejects_unregistered_widths() {
        assert!(DesignSpec::enumerate(12).is_err());
        let msg = DesignSpec::enumerate(12).unwrap_err().to_string();
        assert!(msg.contains("12"), "{msg}");
    }

    #[test]
    fn json_round_trips() {
        for bits in [8u32, 16] {
            for spec in DesignSpec::enumerate(bits).unwrap() {
                let wire = spec.to_json().to_string();
                let back = DesignSpec::from_json(&Json::parse(&wire).unwrap()).unwrap();
                assert_eq!(back, spec, "{wire}");
            }
        }
        assert_eq!(
            DesignSpec::ScaleTrim { h: 3, m: 4 }.to_json().to_string(),
            r#"{"family":"scaleTRIM","h":3,"m":4}"#
        );
    }

    #[test]
    fn json_rejects_invalid_parameters() {
        // Structurally fine, semantically invalid (t >= h) — must be
        // rejected by the grammar re-validation.
        let j = Json::parse(r#"{"family":"TOSAM","t":9,"h":2}"#).unwrap();
        assert!(DesignSpec::from_json(&j).is_err());
        let j = Json::parse(r#"{"family":"warp","x":1}"#).unwrap();
        assert!(DesignSpec::from_json(&j).is_err());
        let j = Json::parse(r#"{"family":"DRUM"}"#).unwrap();
        assert!(DesignSpec::from_json(&j).is_err(), "missing field");
    }

    #[test]
    fn specs_are_usable_as_map_keys() {
        use std::collections::HashMap;
        let mut m: HashMap<DesignSpec, u32> = HashMap::new();
        m.insert(DesignSpec::ScaleTrim { h: 3, m: 4 }, 1);
        m.insert(DesignSpec::ScaleTrim { h: 3, m: 8 }, 2);
        assert_eq!(m[&"scaleTRIM(3,4)".parse::<DesignSpec>().unwrap()], 1);
        assert_eq!(m[&"scaleTRIM(3,8)".parse::<DesignSpec>().unwrap()], 2);
    }
}
