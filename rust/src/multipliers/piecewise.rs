//! Piecewise linearization (paper Sec. IV-D, Eq. 11; the method family of
//! ApproxLP, Imani et al., DAC 2019 [18]).
//!
//! The truncated-sum space `S = X_h + Y_h ∈ [0, 2)` is split into `S`
//! segments; each segment gets its own least-squares linear model
//! `t ≈ α_s·s + β_s` fitted offline. More storage and selection logic than
//! scaleTRIM (two constants per segment, full-precision multiply by α_s),
//! traded for local fit quality — exactly the comparison Table 3 makes.

use super::{leading_one, truncate_fraction, ApproxMultiplier, DesignSpec};
use std::collections::HashMap;
use std::sync::Mutex;

/// Piecewise-linear approximate multiplier with `segments` segments over
/// the truncated-sum space (truncation width `h`).
#[derive(Debug, Clone)]
pub struct PiecewiseLinear {
    bits: u32,
    h: u32,
    segments: u32,
    /// Per-segment (α, β) in 2^-F fixed point.
    coef: Vec<(i64, i64)>,
}

const F: u32 = 24;

impl PiecewiseLinear {
    /// Fit (cached) and construct. Table 3 uses `h = 4`, `segments = 4`.
    pub fn new(bits: u32, h: u32, segments: u32) -> Self {
        assert!(segments >= 1 && h >= 1 && h < bits);
        let coef = cached_fit(bits, h, segments);
        Self {
            bits,
            h,
            segments,
            coef,
        }
    }

    #[inline]
    fn segment(&self, s_int: u64) -> usize {
        let idx = (s_int as u128 * self.segments as u128) >> (self.h + 1);
        (idx as usize).min(self.segments as usize - 1)
    }
}

impl ApproxMultiplier for PiecewiseLinear {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Piecewise {
            h: self.h,
            s: self.segments,
        }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let na = leading_one(a);
        let nb = leading_one(b);
        let s_int = truncate_fraction(a, na, self.h) + truncate_fraction(b, nb, self.h);
        let (alpha, beta) = self.coef[self.segment(s_int)];
        // term = 1 + α·s + β in 2^-F fixed point.
        let s_f = (s_int as i64) << (F - self.h);
        let term = (1i64 << F) + ((alpha as i128 * s_f as i128) >> F) as i64 + beta;
        if term <= 0 {
            return 0;
        }
        ((term as u128) << (na + nb) >> F) as u64
    }
}

/// Offline per-segment least-squares fit of `t = X+Y+XY` on `s = X_h+Y_h`,
/// exact via the same class decomposition the scaleTRIM calibration uses.
fn cached_fit(bits: u32, h: u32, segments: u32) -> Vec<(i64, i64)> {
    static CACHE: Mutex<Option<HashMap<(u32, u32, u32), Vec<(i64, i64)>>>> = Mutex::new(None);
    let mut guard = CACHE.lock().unwrap();
    let map = guard.get_or_insert_with(HashMap::new);
    map.entry((bits, h, segments))
        .or_insert_with(|| {
            let cls = crate::lut::OperandClasses::scan(bits, h);
            let classes = 1usize << h;
            let scale = (1u64 << h) as f64;
            // Per-segment normal-equation sums for t ~ α s + β.
            let m = segments as usize;
            let (mut sw, mut ss, mut sss, mut st, mut sst) =
                (vec![0f64; m], vec![0f64; m], vec![0f64; m], vec![0f64; m], vec![0f64; m]);
            for u in 0..classes {
                let (nu, sxu) = (cls.count[u] as f64, cls.sum_x[u]);
                if nu == 0.0 {
                    continue;
                }
                for v in 0..classes {
                    let (nv, sxv) = (cls.count[v] as f64, cls.sum_x[v]);
                    if nv == 0.0 {
                        continue;
                    }
                    let s_int = (u + v) as u64;
                    let s = s_int as f64 / scale;
                    let seg = (((s_int as u128 * segments as u128) >> (h + 1)) as usize)
                        .min(m - 1);
                    let w = nu * nv;
                    let sum_t = nv * sxu + nu * sxv + sxu * sxv;
                    sw[seg] += w;
                    ss[seg] += w * s;
                    sss[seg] += w * s * s;
                    st[seg] += sum_t;
                    sst[seg] += s * sum_t;
                }
            }
            (0..m)
                .map(|i| {
                    let det = sw[i] * sss[i] - ss[i] * ss[i];
                    let (alpha, beta) = if det.abs() < 1e-12 {
                        // Degenerate segment (single s value): constant fit.
                        (0.0, if sw[i] > 0.0 { st[i] / sw[i] } else { 0.0 })
                    } else {
                        let alpha = (sw[i] * sst[i] - ss[i] * st[i]) / det;
                        let beta = (sss[i] * st[i] - ss[i] * sst[i]) / det;
                        (alpha, beta)
                    };
                    let q = (1u64 << F) as f64;
                    ((alpha * q).round() as i64, (beta * q).round() as i64)
                })
                .collect()
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn table3_configuration_in_range() {
        // Table 3's piecewise S=4 reports mean ARED 2.23 / "MRED" 3.25;
        // our h=4 S=4 fit lands at ~2.2 (matching the mean column).
        let got = mred(&PiecewiseLinear::new(8, 4, 4));
        assert!(
            got > 1.5 && got < 3.6,
            "Piecewise(4,4) MRED {got:.2} outside Table 3 family"
        );
    }

    #[test]
    fn more_segments_not_worse() {
        let s1 = mred(&PiecewiseLinear::new(8, 4, 1));
        let s4 = mred(&PiecewiseLinear::new(8, 4, 4));
        assert!(s4 <= s1 + 1e-9, "S=4 {s4} worse than S=1 {s1}");
    }

    #[test]
    fn zero_bypass() {
        let m = PiecewiseLinear::new(8, 4, 4);
        assert_eq!(m.mul(0, 99), 0);
    }
}
