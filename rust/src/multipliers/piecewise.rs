//! Piecewise linearization (paper Sec. IV-D, Eq. 11; the method family of
//! ApproxLP, Imani et al., DAC 2019 [18]).
//!
//! The truncated-sum space `S = X_h + Y_h ∈ [0, 2)` is split into `S`
//! segments; each segment gets its own least-squares linear model
//! `t ≈ α_s·s + β_s` fitted offline. More storage and selection logic than
//! scaleTRIM (two constants per segment, full-precision multiply by α_s),
//! traded for local fit quality — exactly the comparison Table 3 makes.

use super::{leading_one, narrow_result, truncate_fraction, ApproxMultiplier, DesignSpec};
use std::sync::Arc;

/// Fraction bits of the per-segment (α_s, β_s) fixed-point coefficients.
pub(crate) const PIECEWISE_FRAC_BITS: u32 = 24;

/// Piecewise-linear approximate multiplier with `segments` segments over
/// the truncated-sum space (truncation width `h`).
#[derive(Debug, Clone)]
pub struct PiecewiseLinear {
    bits: u32,
    h: u32,
    segments: u32,
    /// Per-segment (α, β) in 2^-F fixed point (allocation shared with the
    /// unified calibration cache).
    coef: Arc<Vec<(i64, i64)>>,
}

const F: u32 = PIECEWISE_FRAC_BITS;

impl PiecewiseLinear {
    /// Fit (cached process-wide) and construct. Table 3 uses `h = 4`,
    /// `segments = 4`. Panics on invalid parameters —
    /// [`PiecewiseLinear::try_new`] is the typed form.
    pub fn new(bits: u32, h: u32, segments: u32) -> Self {
        // lint:allow(no-panic): documented panicking constructor; try_new is the typed form
        Self::try_new(bits, h, segments).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`PiecewiseLinear::new`] as a typed error: validity is decided by
    /// [`DesignSpec::validate`] — the same single path `DesignSpec::build`
    /// and `ScaleTrim::try_new` use, so the constructors can no longer
    /// drift apart on what they accept (`h ≥ 1` here, `h ≥ 2` for
    /// scaleTRIM, both spelled in `spec::validate_params`). The fit
    /// resolves through the unified calibration cache
    /// ([`crate::calib::cache()`]).
    pub fn try_new(bits: u32, h: u32, segments: u32) -> crate::Result<Self> {
        let spec = DesignSpec::Piecewise { h, s: segments };
        spec.validate(bits)?;
        Ok(Self {
            bits,
            h,
            segments,
            coef: crate::calib::cache().piecewise_fit(bits, h, segments),
        })
    }

    #[inline]
    fn segment(&self, s_int: u64) -> usize {
        crate::lut::segment_of(s_int, self.segments, self.h, &[])
    }
}

impl ApproxMultiplier for PiecewiseLinear {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Piecewise {
            h: self.h,
            s: self.segments,
        }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    fn calib_cost_ops(&self) -> f64 {
        // Exhaustive-scan fit — priced by the strategy's own cost model.
        crate::calib::calibrator(crate::calib::CalibStrategy::Exhaustive)
            .cost_ops(self.bits, self.h)
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let na = leading_one(a);
        let nb = leading_one(b);
        debug_assert!(
            na < self.bits && nb < self.bits,
            "leading-one position exceeds the declared width"
        );
        let s_int = truncate_fraction(a, na, self.h) + truncate_fraction(b, nb, self.h);
        debug_assert!(
            self.h <= F && s_int < (1u64 << (self.h + 1)),
            "truncated sum exceeds the F-bit fixed point"
        );
        let (alpha, beta) = self.coef[self.segment(s_int)];
        // term = 1 + α·s + β in 2^-F fixed point.
        let s_f = (s_int as i64) << (F - self.h);
        let scaled = (alpha as i128 * s_f as i128) >> F;
        debug_assert!(
            scaled >= i64::MIN as i128 && scaled <= i64::MAX as i128,
            "α·s term exceeds the i64 datapath"
        );
        let term = (1i64 << F) + scaled as i64 + beta;
        if term <= 0 {
            return 0;
        }
        narrow_result((term as u128) << (na + nb), F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn table3_configuration_in_range() {
        // Table 3's piecewise S=4 reports mean ARED 2.23 / "MRED" 3.25;
        // our h=4 S=4 fit lands at ~2.2 (matching the mean column).
        let got = mred(&PiecewiseLinear::new(8, 4, 4));
        assert!(
            got > 1.5 && got < 3.6,
            "Piecewise(4,4) MRED {got:.2} outside Table 3 family"
        );
    }

    #[test]
    fn more_segments_not_worse() {
        let s1 = mred(&PiecewiseLinear::new(8, 4, 1));
        let s4 = mred(&PiecewiseLinear::new(8, 4, 4));
        assert!(s4 <= s1 + 1e-9, "S=4 {s4} worse than S=1 {s1}");
    }

    #[test]
    fn zero_bypass() {
        let m = PiecewiseLinear::new(8, 4, 4);
        assert_eq!(m.mul(0, 99), 0);
    }

    /// Constructor validation is the spec's: h ≥ 1 stays legal here (the
    /// spec grammar says so), h ≥ bits is a typed error, and the message
    /// comes from the same path as `DesignSpec::build`.
    #[test]
    fn try_new_agrees_with_spec_build() {
        assert!(PiecewiseLinear::try_new(8, 1, 4).is_ok(), "h = 1 is a legal fit");
        let direct = PiecewiseLinear::try_new(8, 9, 4).unwrap_err().to_string();
        let via_spec = DesignSpec::Piecewise { h: 9, s: 4 }.build(8).unwrap_err().to_string();
        assert_eq!(direct, via_spec, "one error path for both constructions");
        assert!(PiecewiseLinear::try_new(8, 0, 4).is_err());
        assert!(PiecewiseLinear::try_new(8, 4, 0).is_err());
    }
}
