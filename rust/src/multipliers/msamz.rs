//! MSAMZ — Most Significant one-driven Approximate Multiplier (Huang, Gong,
//! Chen, Wang, Electronics 2024; paper ref [32]).
//!
//! The operand space is partitioned by an approximation factor `k` and a
//! precision factor `m`: the `m` bits below the most-significant one are
//! kept exact, the next `k` bits are approximated with the *one-dominating*
//! strategy (the partial products of that region are replaced by the
//! bitwise OR of the contributing operand bits — cheap, biased-high), and
//! anything below is dropped.

use super::{leading_one, ApproxMultiplier, DesignSpec};

/// MSAMZ(k, m) behavioural model (one-dominating variant with
/// compensation).
#[derive(Debug, Clone)]
pub struct Msamz {
    bits: u32,
    k: u32,
    m: u32,
}

impl Msamz {
    /// New MSAMZ with approximation factor `k` and precision factor `m`.
    pub fn new(bits: u32, k: u32, m: u32) -> Self {
        assert!(m >= 1 && m + k <= 2 * bits);
        Self { bits, k, m }
    }

    /// Split an operand into the exact high window (m bits incl. the
    /// leading one region) and the one-dominated approximate tail.
    #[inline]
    fn windows(&self, v: u64) -> (u64, u64, u32) {
        let n = leading_one(v);
        let width = n + 1;
        if width <= self.m {
            return (v, 0, 0);
        }
        let shift = width - self.m;
        debug_assert!(shift < u64::BITS, "window shift exceeds the u64 range");
        (v >> shift, v & ((1u64 << shift) - 1), shift)
    }
}

impl ApproxMultiplier for Msamz {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Msamz { k: self.k, m: self.m }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let (ah, al, sa) = self.windows(a);
        let (bh, bl, sb) = self.windows(b);
        debug_assert!(
            sa < self.bits && sb < self.bits,
            "window shift exceeds the declared width"
        );
        // Exact product of the high windows (an m×m multiplier).
        let hh = (ah * bh) << (sa + sb);
        // One-dominating approximation of the cross terms: the tails are
        // OR-compressed into their top k bits and multiplied by the high
        // windows (shift-add in hardware).
        let compress = |tail: u64, shift: u32| -> u64 {
            if shift == 0 || self.k == 0 {
                return 0;
            }
            let keep = self.k.min(shift);
            debug_assert!(keep <= shift && shift < u64::BITS, "tail shift exceeds the u64 range");
            tail >> (shift - keep) << (shift - keep)
        };
        let al_c = compress(al, sa);
        let bl_c = compress(bl, sb);
        let cross = (ah * bl_c) << sa | (bh * al_c) << sb;
        hh + cross
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn small_operands_exact() {
        let m = Msamz::new(8, 4, 4);
        for a in 1..16u64 {
            for b in 1..16u64 {
                assert_eq!(m.mul(a, b), a * b);
            }
        }
    }

    #[test]
    fn precision_factor_controls_accuracy() {
        let coarse = mred(&Msamz::new(8, 2, 3));
        let fine = mred(&Msamz::new(8, 2, 6));
        assert!(fine < coarse, "{fine} !< {coarse}");
    }

    #[test]
    fn in_published_family_range() {
        // The MSAMZ paper's 8-bit points sit in the ~1–10% MRED band.
        let got = mred(&Msamz::new(8, 4, 4));
        assert!(got < 10.0, "MSAMZ(4,4) MRED {got:.2} out of family");
    }
}
