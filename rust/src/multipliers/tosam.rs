//! TOSAM — Truncation- and rOunding-based Scalable Approximate Multiplier
//! (Vahdat, Kamal, Afzali-Kusha, Pedram, TVLSI 2019; paper ref [16]).
//!
//! `A×B = 2^(nA+nB)(1 + X + Y + X·Y)` with the sum part computed from
//! `h`-bit truncated fractions and the product part from `(t+1)`-bit
//! *unbiased* fractions (`t` truncated bits with a `1` concatenated at the
//! LSB — the "rounding" compensation of Table 1):
//!
//! ```text
//!   term = 1 + X_h + Y_h + X_{t∘1} · Y_{t∘1}
//! ```
//!
//! Interpretation note: the scaleTRIM paper's prose swaps the roles of `t`
//! and `h`; the assignment above (adder width `h`, multiplier width `t+1`)
//! is the one that reproduces the published MRED of every TOSAM(t,h) config
//! in Table 4 to within ~0.2 pp (e.g. TOSAM(1,5): ours 4.09 vs paper 4.09).

use super::{leading_one, narrow_result, truncate_fraction, ApproxMultiplier, DesignSpec};

/// TOSAM(t, h) behavioural model.
#[derive(Debug, Clone)]
pub struct Tosam {
    bits: u32,
    t: u32,
    h: u32,
}

impl Tosam {
    /// New TOSAM; the paper evaluates `t < h` (t ∈ 0..=3, h ∈ 2..=7).
    pub fn new(bits: u32, t: u32, h: u32) -> Self {
        assert!(h >= 1 && h < bits && t < bits);
        Self { bits, t, h }
    }
}

impl ApproxMultiplier for Tosam {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Tosam { t: self.t, h: self.h }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let (t, h) = (self.t, self.h);
        let na = leading_one(a);
        let nb = leading_one(b);
        debug_assert!(
            na < self.bits && nb < self.bits,
            "leading-one position exceeds the declared width"
        );
        // Adder part: h-bit truncated fractions (units 2^-h).
        let xh = truncate_fraction(a, na, h);
        let yh = truncate_fraction(b, nb, h);
        // Multiplier part: t-bit truncated fractions with '1' concatenated
        // (units 2^-(t+1)) — an unbiased (t+1)×(t+1) multiplier input.
        let xt1 = (truncate_fraction(a, na, t) << 1) | 1;
        let yt1 = (truncate_fraction(b, nb, t) << 1) | 1;

        // Fixed point with F fraction bits.
        const F: u32 = 24;
        let one = 1u128 << F;
        let sum_shift = F - h;
        let prod_shift = F - 2 * (t + 1);
        debug_assert!(
            sum_shift < F && prod_shift < F,
            "derived shifts exceed the F-bit datapath"
        );
        let sum = ((xh + yh) as u128) << sum_shift;
        let prod = ((xt1 * yt1) as u128) << prod_shift;
        let term = one + sum + prod;
        narrow_result(term << (na + nb), F)
    }

    /// Monomorphized batch kernel: `t`, `h` and the derived fixed-point
    /// shifts are hoisted out of the loop.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        const F: u32 = 24;
        let (t, h) = (self.t, self.h);
        let one = 1u128 << F;
        let sum_shift = F - h;
        let prod_shift = F - 2 * (t + 1);
        debug_assert!(
            sum_shift < F && prod_shift < F,
            "hoisted shifts exceed the F-bit datapath"
        );
        for ((&av, &bv), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = if av == 0 || bv == 0 {
                0
            } else {
                let na = leading_one(av);
                let nb = leading_one(bv);
                debug_assert!(
                    na < self.bits && nb < self.bits,
                    "leading-one position exceeds the declared width"
                );
                let xh = truncate_fraction(av, na, h);
                let yh = truncate_fraction(bv, nb, h);
                let xt1 = (truncate_fraction(av, na, t) << 1) | 1;
                let yt1 = (truncate_fraction(bv, nb, t) << 1) | 1;
                let term = one + (((xh + yh) as u128) << sum_shift)
                    + (((xt1 * yt1) as u128) << prod_shift);
                narrow_result(term << (na + nb), F)
            };
        }
    }

    /// Hand-vectorized lane kernel: batched LOD over the lane block,
    /// branchless zero pre-masking (placeholder operand `1` has LOD 0 and
    /// empty fractions, so `xt1 = yt1 = 1` — well defined), fixed-point
    /// shifts hoisted; the sub-lane tail delegates to `mul_batch`.
    fn mul_batch_simd(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        use crate::simd;
        const F: u32 = 24;
        let (t, h) = (self.t, self.h);
        let one = 1u128 << F;
        let sum_shift = F - h;
        let prod_shift = F - 2 * (t + 1);
        debug_assert!(
            sum_shift < F && prod_shift < F,
            "hoisted shifts exceed the F-bit datapath"
        );
        simd::drive_lanes(
            a,
            b,
            out,
            |xa, xb| {
                let keep = simd::nonzero_flags(xa, xb);
                let xm = simd::mask_zero_to_one(xa);
                let ym = simd::mask_zero_to_one(xb);
                let na = simd::leading_one_lanes(&xm);
                let nb = simd::leading_one_lanes(&ym);
                let mut r = [0u64; simd::LANES];
                for (i, r_i) in r.iter_mut().enumerate() {
                    debug_assert!(
                        na[i] < self.bits && nb[i] < self.bits,
                        "lane leading-one exceeds the declared width"
                    );
                    let xh = truncate_fraction(xm[i], na[i], h);
                    let yh = truncate_fraction(ym[i], nb[i], h);
                    let xt1 = (truncate_fraction(xm[i], na[i], t) << 1) | 1;
                    let yt1 = (truncate_fraction(ym[i], nb[i], t) << 1) | 1;
                    let term = one
                        + (((xh + yh) as u128) << sum_shift)
                        + (((xt1 * yt1) as u128) << prod_shift);
                    *r_i = narrow_result(term << (na[i] + nb[i]), F) * keep[i];
                }
                r
            },
            |ta, tb, tout| self.mul_batch(ta, tb, tout),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn zero_bypass() {
        let m = Tosam::new(8, 1, 5);
        assert_eq!(m.mul(0, 200), 0);
        assert_eq!(m.mul(200, 0), 0);
    }

    #[test]
    fn mred_matches_paper_anchors() {
        // Table 4 anchors with the measured deltas from our interpretation.
        for (t, h, paper, tol) in [
            (0u32, 2u32, 10.38f64, 0.5),
            (0, 3, 7.58, 0.5),
            (1, 3, 5.76, 0.5),
            (1, 5, 4.09, 0.25),
            (2, 5, 2.36, 0.4),
            (3, 7, 0.98, 0.3),
        ] {
            let m = Tosam::new(8, t, h);
            let got = mred(&m);
            assert!(
                (got - paper).abs() < tol,
                "TOSAM({t},{h}): MRED {got:.2} vs paper {paper}"
            );
        }
    }

    #[test]
    fn accuracy_improves_with_h() {
        let coarse = mred(&Tosam::new(8, 1, 2));
        let fine = mred(&Tosam::new(8, 1, 6));
        assert!(fine < coarse);
    }
}
