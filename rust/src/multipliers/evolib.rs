//! EvoApproxLib surrogate points (Mrazek et al., DATE 2017; paper ref [31]).
//!
//! The paper compares against four Pareto-optimal *evolved* 8-bit
//! multipliers ("EVO-lib1..4", Table 4: MRED 0.019 / 0.13 / 0.82 / 5.03 %).
//! The evolved netlists themselves are opaque; what the comparison needs is
//! a conventionally-synthesizable design at each published MRED with
//! commensurate cost. We use the canonical truncation family — the
//! broken-array multiplier (BAM): an exact array multiplier with the `j`
//! least-significant partial-product columns removed. The mapping
//! `k → j = {1→1, 2→2, 3→4, 4→7}` lands each surrogate on the published
//! MRED (measured: 0.018 / 0.078 / 0.56 / 5.2 %). See DESIGN.md
//! §Substitutions.

use super::{ApproxMultiplier, DesignSpec};

/// EvoLib-k surrogate: broken-array multiplier.
#[derive(Debug, Clone)]
pub struct EvoLibSurrogate {
    bits: u32,
    k: u32,
    dropped_cols: u32,
}

impl EvoLibSurrogate {
    /// New surrogate for the paper's EVO-lib`k` point (k ∈ 1..=4).
    pub fn new(bits: u32, k: u32) -> Self {
        assert!((1..=4).contains(&k));
        let dropped_cols = match k {
            1 => 1,
            2 => 2,
            3 => 4,
            _ => 7,
        };
        Self {
            bits,
            k,
            dropped_cols,
        }
    }

    /// Number of truncated partial-product columns.
    pub fn dropped_columns(&self) -> u32 {
        self.dropped_cols
    }
}

impl ApproxMultiplier for EvoLibSurrogate {
    fn spec(&self) -> DesignSpec {
        DesignSpec::EvoLib { k: self.k }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        // Exact product minus the contribution of the dropped columns:
        // sum of pp bits a_i·b_j with i+j < dropped_cols.
        let j = self.dropped_cols;
        let mut dropped = 0u64;
        for col in 0..j {
            for i in 0..=col.min(self.bits - 1) {
                let jj = col - i;
                if jj >= self.bits {
                    continue;
                }
                debug_assert!(
                    i < self.bits && jj < self.bits && col < u64::BITS,
                    "partial-product index exceeds the operand width"
                );
                dropped += (((a >> i) & 1) & ((b >> jj) & 1)) << col;
            }
        }
        a * b - dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn surrogates_land_on_published_mred() {
        // Paper Table 4 MRED vs our BAM surrogates (relative band).
        for (k, paper, lo, hi) in [
            (1u32, 0.019f64, 0.01, 0.03),
            (2, 0.13, 0.05, 0.25),
            (3, 0.82, 0.3, 1.3),
            (4, 5.03, 3.5, 6.5),
        ] {
            let got = mred(&EvoLibSurrogate::new(8, k));
            assert!(
                (lo..=hi).contains(&got),
                "EVO-lib{k}: MRED {got:.3} not near paper {paper}"
            );
        }
    }

    #[test]
    fn never_overestimates() {
        // Truncation only removes positive contributions.
        let m = EvoLibSurrogate::new(8, 4);
        for a in 1..256u64 {
            for b in 1..256u64 {
                assert!(m.mul(a, b) <= a * b);
            }
        }
    }

    #[test]
    fn large_products_nearly_exact() {
        let m = EvoLibSurrogate::new(8, 2);
        assert!((m.mul(200, 200) as i64 - 40_000i64).abs() <= 3);
    }
}
