//! ILM — Improved Logarithmic Multiplier for energy-efficient neural
//! computing (Ansari, Cockburn, Han, IEEE TC 2021; paper refs [30, 36]).
//!
//! Mitchell's weakness is its one-sided error; ILM uses *nearest-one*
//! detection (round each operand to the nearest power of two) so mantissas
//! lie in `[-1/3, 1/2)` and errors straddle zero:
//!
//! ```text
//!   A = 2^kA (1 + x),  x ∈ [-1/3, 1/2)
//!   A×B ≈ 2^(kA+kB) (1 + x + y)
//! ```
//!
//! `ILM-k` additionally truncates each mantissa magnitude to `k` fraction
//! bits (`k = 0` means no truncation, the paper's ILM0).

use super::{leading_one, narrow_result, ApproxMultiplier, DesignSpec};

/// ILM-k behavioural model.
#[derive(Debug, Clone)]
pub struct Ilm {
    bits: u32,
    k: u32,
}

const F: u32 = 24;

impl Ilm {
    /// New ILM; `k = 0` disables mantissa truncation, `k > 0` keeps `k`
    /// fraction bits (paper's ILM5 keeps 5... of the *complement* path,
    /// which costs accuracy — see Table 4: ILM5 MRED 9.51 vs ILM0 2.69).
    pub fn new(bits: u32, k: u32) -> Self {
        Self { bits, k }
    }

    /// Nearest-one characteristic and signed mantissa in 2^-F units.
    #[inline]
    fn decompose(&self, v: u64) -> (u32, i64) {
        debug_assert!(
            v < (1u64 << self.bits),
            "operand exceeds the declared width"
        );
        let n = leading_one(v);
        debug_assert!(n < self.bits, "leading-one position exceeds the declared width");
        let base = 1u64 << n;
        // Nearest power of two: round up when v ≥ 1.5·2^n (integer compare).
        let (k_char, x) = if 2 * v >= 3 * base && n + 1 < 64 {
            let up = base << 1;
            // x = v/2^(n+1) - 1 ∈ [-1/4, 0)
            (n + 1, ((v as i64 - up as i64) << F) >> (n + 1))
        } else {
            (n, ((v as i64 - base as i64) << F) >> n)
        };
        let x = if self.k > 0 {
            // Truncate mantissa magnitude to k fraction bits.
            let q = F - self.k;
            debug_assert!(q < F, "truncated mantissa width exceeds the F-bit datapath");
            let mag = x.unsigned_abs() >> q << q;
            if x < 0 {
                -(mag as i64)
            } else {
                mag as i64
            }
        } else {
            x
        };
        (k_char, x)
    }
}

impl ApproxMultiplier for Ilm {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Ilm { k: self.k }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let (ka, x) = self.decompose(a);
        let (kb, y) = self.decompose(b);
        debug_assert!(
            ka <= self.bits && kb <= self.bits,
            "nearest-one characteristic exceeds the declared width"
        );
        let term = (1i64 << F) + x + y;
        if term <= 0 {
            return 0;
        }
        narrow_result((term as u128) << (ka + kb), F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn powers_of_two_exact() {
        let m = Ilm::new(8, 0);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(m.mul(1 << i, 1 << j), 1u64 << (i + j));
            }
        }
    }

    #[test]
    fn error_is_two_sided() {
        // Unlike Mitchell, ILM must over- and under-estimate.
        let m = Ilm::new(8, 0);
        let mut over = false;
        let mut under = false;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let p = m.mul(a, b);
                over |= p > a * b;
                under |= p < a * b;
            }
        }
        assert!(over && under);
    }

    #[test]
    fn ilm0_beats_mitchell() {
        // Table 4: ILM0 2.69 vs Mitchell 3.76.
        let ilm = mred(&Ilm::new(8, 0));
        let mitchell = mred(&crate::multipliers::Mitchell::new(8));
        assert!(ilm < mitchell, "ILM0 {ilm:.2} !< Mitchell {mitchell:.2}");
        assert!((ilm - 2.69).abs() < 0.5, "ILM0 MRED {ilm:.2} vs paper 2.69");
    }

    #[test]
    fn truncation_degrades() {
        assert!(mred(&Ilm::new(8, 2)) > mred(&Ilm::new(8, 0)));
    }
}
