//! Bit-accurate behavioural models of scaleTRIM and every baseline multiplier
//! the paper compares against (Sec. II Table 1, Sec. IV Figs. 9–13).
//!
//! Every design implements [`ApproxMultiplier`]: an `n`-bit unsigned integer
//! multiplier evaluated as `mul(a, b)` over `a, b ∈ [0, 2^n)`. Signed use is
//! sign-magnitude wrapping (paper Sec. III-D); [`signed_mul`] provides it.
//!
//! ## The batched kernel plane
//!
//! Every hot path in the system (error sweeps, product-LUT construction,
//! CNN MAC evaluation) consumes multipliers in bulk, so the trait also
//! carries [`ApproxMultiplier::mul_batch`]: one virtual call per operand
//! *chunk* instead of one per pair. The default method loops over `mul`;
//! the hottest designs (scaleTRIM, Mitchell, MBM, DRUM, DSM, TOSAM, exact)
//! override it with monomorphized loops that hoist parameter loads
//! (`h`, `ΔEE`, the compensation-LUT base pointer, segment tables) out of
//! the loop and let LLVM inline and vectorise the datapath. Above that
//! sits [`ApproxMultiplier::mul_batch_simd`] — the explicit SIMD kernel
//! plane ([`crate::simd`]): 8-wide branch-free lane blocks with batched
//! LOD and branchless zero masking, defaulting to `mul_batch` for designs
//! without a hand-written lane kernel. For repeat evaluation of one
//! config, [`CompiledMul`] folds any design into a full product table
//! (widths ≤ 12 bits) so every multiply becomes a load.
//!
//! The zoo (one module per design):
//!
//! | module | paper | family |
//! |---|---|---|
//! | [`scaletrim`] | this paper | truncation + linearization + LUT compensation |
//! | [`drum`] | Hashemi'15 [11] | dynamic-range unbiased truncation |
//! | [`dsm`] | Narayanamoorthy'15 [1] | static segment method |
//! | [`tosam`] | Vahdat'19 [16] | truncation + rounding |
//! | [`letam`] | Vahdat'17 [17] | truncation |
//! | [`roba`] | Zendegani'17 [12] | rounding to powers of two |
//! | [`mitchell`] | Mitchell'62 [28] | logarithmic |
//! | [`mbm`] | Saadat'18 [7] | minimally-biased Mitchell |
//! | [`ilm`] | Ansari'21 [36] | improved (nearest-one) logarithmic |
//! | [`lodii`] | Ansari'21 [37] | Mitchell with approximate LOD |
//! | [`axm`] | Deepsita'23 [22] | recursive approximate MAC |
//! | [`scdm`] | Shakibhamedan'24 [19] | carry-disregard array |
//! | [`msamz`] | Huang'24 [32] | MSB-guided shift-add |
//! | [`piecewise`] | Imani'19 [18] / Sec. IV-D | piecewise linearization |
//! | [`evolib`] | Mrazek'17 [31] | broken-array surrogates (see DESIGN.md) |
//! | [`compiled`] | — | full-product-table kernel over any design above |

pub mod axm;
pub mod compiled;
pub mod drum;
pub mod dsm;
pub mod evolib;
pub mod exact;
pub mod ilm;
pub mod letam;
pub mod lodii;
pub mod mbm;
pub mod mitchell;
pub mod msamz;
pub mod piecewise;
pub mod roba;
pub mod scaletrim;
pub mod scdm;
pub mod spec;
pub mod tosam;

pub use axm::Axm;
pub use compiled::CompiledMul;
pub use drum::Drum;
pub use dsm::Dsm;
pub use evolib::EvoLibSurrogate;
pub use exact::Exact;
pub use ilm::Ilm;
pub use letam::Letam;
pub use lodii::MitchellLodII;
pub use mbm::Mbm;
pub use mitchell::Mitchell;
pub use msamz::Msamz;
pub use piecewise::PiecewiseLinear;
pub use roba::Roba;
pub use scaletrim::ScaleTrim;
pub use scdm::Scdm;
pub use spec::{DesignSpec, ParseSpecError};
pub use tosam::Tosam;

/// An `n`-bit unsigned approximate multiplier behavioural model.
///
/// Implementations must be pure (no interior mutability on the `mul` path) so
/// sweeps can share one instance across threads.
pub trait ApproxMultiplier: Send + Sync {
    /// Typed identity of this configuration — the single key every
    /// identity-consuming layer (hardware model, LUT cache, coordinator
    /// lanes, DSE points) routes on. For zoo designs
    /// `spec().build(bits())` reconstructs an observably identical
    /// instance.
    fn spec(&self) -> DesignSpec;

    /// Display name, matching the paper's config labels (e.g.
    /// `scaleTRIM(3,4)`). Default: the spec's label; wrappers that decorate
    /// another design (e.g. [`CompiledMul`]) override it.
    fn name(&self) -> String {
        self.spec().to_string()
    }

    /// Operand bit-width `n`; `mul` accepts operands in `[0, 2^n)`.
    fn bits(&self) -> u32;

    /// Which calibration strategy produced this instance's design-time
    /// constants. Part of the instance's identity in the unified
    /// calibration cache (`(spec, bits, strategy, kind)` keys): a
    /// sampled-calibrated scaleTRIM must never share a product LUT with
    /// the exhaustively calibrated one. Designs with no design-time
    /// calibration report the default
    /// ([`Exhaustive`](crate::calib::CalibStrategy::Exhaustive)) — for
    /// them every strategy is trivially the same design.
    fn calib_strategy(&self) -> crate::calib::CalibStrategy {
        crate::calib::CalibStrategy::Exhaustive
    }

    /// Rough design-time calibration cost in datapath-equivalent
    /// operations — the DSE's calibration-cost objective. `0.0` for
    /// designs that need no calibration (truncation/logarithmic families);
    /// scaleTRIM and the piecewise baseline report their strategy's cost
    /// model.
    fn calib_cost_ops(&self) -> f64 {
        0.0
    }

    /// Approximate product of two unsigned operands.
    fn mul(&self, a: u64, b: u64) -> u64;

    /// Element-wise approximate products over operand slices:
    /// `out[i] = mul(a[i], b[i])`.
    ///
    /// This is the bulk entry point of the batched kernel plane — sweeps,
    /// LUT builders and MAC loops call it once per chunk, paying dynamic
    /// dispatch per *chunk* rather than per pair. Overrides must be
    /// observably identical to the per-element default (enforced by
    /// `tests/prop_multipliers.rs`); they exist only to hoist parameter
    /// loads and enable inlining.
    ///
    /// Panics when the three slices differ in length.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = self.mul(x, y);
        }
    }

    /// Element-wise approximate products through the explicit SIMD kernel
    /// plane ([`crate::simd`]): operands stream in structure-of-arrays
    /// layout through [`LANES`](crate::simd::LANES)-wide branch-free lane
    /// blocks (batched leading-one detection, branchless zero
    /// pre-masking), with the sub-lane tail delegated to `mul_batch`.
    ///
    /// The default falls back to `mul_batch` — every design gets the SIMD
    /// entry point, and only the hottest kernels (scaleTRIM, TOSAM,
    /// Mitchell, exact) override it with hand-unrolled lane bodies.
    /// Overrides must be observably identical to `mul` per element,
    /// including at zero operands and off-lane-width batch lengths
    /// (enforced by `tests/prop_multipliers.rs` over every enumerable
    /// spec).
    fn mul_batch_simd(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        self.mul_batch(a, b, out);
    }

    /// Exact product for reference (identical for every design).
    fn exact(&self, a: u64, b: u64) -> u64 {
        a * b
    }
}

/// Position of the most significant set bit ("leading one"), i.e.
/// `⌊log2 v⌋`. Panics in debug builds when `v == 0` — callers must apply the
/// zero-detection bypass first, exactly like the hardware (Fig. 8a).
#[inline]
pub fn leading_one(v: u64) -> u32 {
    debug_assert!(v != 0, "leading_one(0): zero-detect must run first");
    63 - v.leading_zeros()
}

/// Sign-magnitude wrapper for signed×signed use (paper Sec. III-D, refs
/// [11, 35]): multiply magnitudes with the unsigned design, restore the sign.
pub fn signed_mul(m: &dyn ApproxMultiplier, a: i64, b: i64) -> i64 {
    let sign = (a < 0) ^ (b < 0);
    // analyze:allow(cast-range): 32-bit magnitude products occupy up to 64
    // bits; reinterpreting the top bit is the documented wrapping contract.
    let p = m.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
    if sign {
        -p
    } else {
        p
    }
}

/// Final output stage shared by every shift-add kernel: drop the `f`
/// fraction bits of the fixed-point total and narrow to the `u64` result
/// bus. Centralising the narrowing gives the whole zoo one checked
/// truncation site — debug builds verify the post-shift value fits the
/// bus (it always does: an `n`-bit design's product occupies at most `2n ≤
/// 64` bits), so the static analyzer and the runtime enforce the same
/// datapath-width invariant.
#[inline(always)]
pub(crate) fn narrow_result(total: u128, f: u32) -> u64 {
    debug_assert!(f < u128::BITS, "fraction width exceeds the u128 datapath");
    let shifted = total >> f;
    debug_assert!(
        shifted <= u64::MAX as u128,
        "kernel result overflows the u64 result bus"
    );
    shifted as u64
}

/// Truncate the sub-leading-one fraction of operand `v` (leading one at
/// `n`) to `h` bits, zero-padding on the right when fewer than `h` fraction
/// bits exist (paper Sec. III-D truncation unit). Returns `X_h` as an
/// integer in units of `2^-h`.
#[inline]
pub fn truncate_fraction(v: u64, n: u32, h: u32) -> u64 {
    debug_assert!(n < u64::BITS && h < u64::BITS, "fraction widths exceed the u64 range");
    let frac = v & ((1u64 << n) - 1); // bits below the leading one
    if n >= h {
        frac >> (n - h)
    } else {
        frac << (h - n)
    }
}

/// All 8-bit configurations evaluated in the paper's Fig. 9 / Table 4, in
/// paper order. The central registry used by the DSE and repro harnesses —
/// regenerated from [`DesignSpec::enumerate`]'s data tables, so the
/// registry and the typed identity plane can never drift apart.
pub fn paper_configs_8bit() -> Vec<Box<dyn ApproxMultiplier>> {
    build_zoo(8)
}

/// Representative 16-bit configurations (paper Fig. 10); see
/// [`paper_configs_8bit`].
pub fn paper_configs_16bit() -> Vec<Box<dyn ApproxMultiplier>> {
    build_zoo(16)
}

#[allow(clippy::expect_used)]
fn build_zoo(bits: u32) -> Vec<Box<dyn ApproxMultiplier>> {
    DesignSpec::enumerate(bits)
        // lint:allow(no-panic): callers pass registry widths only; the zoo tests pin this
        .expect("registry widths are always enumerable")
        .iter()
        .map(|s| {
            s.build(bits)
                // lint:allow(no-panic): a rejected registry spec is a registration bug
                .unwrap_or_else(|e| panic!("registry spec {s} invalid at {bits} bits: {e}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leading_one_positions() {
        assert_eq!(leading_one(1), 0);
        assert_eq!(leading_one(2), 1);
        assert_eq!(leading_one(3), 1);
        assert_eq!(leading_one(128), 7);
        assert_eq!(leading_one(255), 7);
        assert_eq!(leading_one(48), 5);
        assert_eq!(leading_one(81), 6);
    }

    #[test]
    fn truncate_fraction_pads_and_cuts() {
        // 48 = 0b110000, n=5, fraction 0.10000 -> h=3 keeps 0b100 (= 0.5)
        assert_eq!(truncate_fraction(48, 5, 3), 0b100);
        // 81 = 0b1010001, n=6, fraction 0.010001 -> h=3 keeps 0b010 (= 0.25)
        assert_eq!(truncate_fraction(81, 6, 3), 0b010);
        // 3 = 0b11, n=1: single fraction bit, h=3 pads 0b1 -> 0b100
        assert_eq!(truncate_fraction(3, 1, 3), 0b100);
        // exactly a power of two: fraction is zero
        assert_eq!(truncate_fraction(64, 6, 3), 0);
    }

    #[test]
    fn signed_mul_signs() {
        let m = Exact::new(8);
        assert_eq!(signed_mul(&m, -3, 5), -15);
        assert_eq!(signed_mul(&m, -3, -5), 15);
        assert_eq!(signed_mul(&m, 3, 5), 15);
        assert_eq!(signed_mul(&m, 0, -5), 0);
    }

    #[test]
    fn registry_nonempty_and_unique_names() {
        let zoo = paper_configs_8bit();
        assert!(zoo.len() > 40, "expected full 8-bit zoo, got {}", zoo.len());
        let mut names: Vec<String> = zoo.iter().map(|m| m.name()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len(), "duplicate config names in registry");
    }

    #[test]
    fn registry_16bit_nonempty_and_unique_names() {
        let zoo = paper_configs_16bit();
        assert!(
            zoo.len() > 20,
            "expected full 16-bit zoo, got {}",
            zoo.len()
        );
        for m in &zoo {
            assert_eq!(m.bits(), 16, "{} registered at wrong width", m.name());
        }
        let mut names: Vec<String> = zoo.iter().map(|m| m.name()).collect();
        names.sort();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len(), "duplicate config names in 16-bit registry");
    }

    #[test]
    fn registries_are_generated_from_enumerate() {
        for bits in [8u32, 16] {
            let zoo = build_zoo(bits);
            let specs = DesignSpec::enumerate(bits).unwrap();
            assert_eq!(zoo.len(), specs.len());
            for (m, s) in zoo.iter().zip(&specs) {
                assert_eq!(m.spec(), *s, "instance/spec drift at {bits} bits");
                assert_eq!(m.name(), s.to_string(), "name must be the spec label");
            }
        }
    }

    #[test]
    fn default_mul_batch_matches_scalar() {
        // The default method is the reference the monomorphized overrides
        // are property-tested against; pin its semantics here.
        let m = Exact::new(8);
        let a = [0u64, 1, 7, 255, 128];
        let b = [5u64, 0, 3, 255, 2];
        let mut out = [0u64; 5];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..a.len() {
            assert_eq!(out[i], m.mul(a[i], b[i]));
        }
    }

    #[test]
    #[should_panic(expected = "mul_batch")]
    fn mul_batch_rejects_length_mismatch() {
        let m = Exact::new(8);
        let mut out = [0u64; 2];
        m.mul_batch(&[1, 2, 3], &[1, 2, 3], &mut out);
    }
}
