//! DSM — Segment Method multiplier (Narayanamoorthy et al., TVLSI 2015;
//! paper ref [1]).
//!
//! An `m`-bit segment is taken from one of a small set of *fixed* bit
//! positions of each `n`-bit operand — the position is steered so the
//! segment always contains the operand's leading one (that is the method's
//! defining property; with only two positions this requires `m ≥ n/2`, so
//! for narrower segments the fixed-position set grows, stepping by `m−1`
//! as in the multi-segment variants of the original paper). The two
//! segments feed an exact `m×m` multiplier; no error compensation is
//! applied (Table 1).

use super::{leading_one, ApproxMultiplier, DesignSpec};

/// DSM(m) behavioural model.
#[derive(Debug, Clone)]
pub struct Dsm {
    bits: u32,
    m: u32,
    /// Fixed segment start positions, ascending (always contains 0).
    positions: Vec<u32>,
}

impl Dsm {
    /// New DSM with segment width `m`.
    pub fn new(bits: u32, m: u32) -> Self {
        assert!(m >= 2 && m < bits);
        // Fixed positions 0, m-1, 2(m-1), …, capped at n-m: consecutive
        // positions differ by at most m-1, so every leading-one position is
        // covered by some window [p, p+m).
        let mut positions = Vec::new();
        let mut p = 0;
        while p < bits - m {
            positions.push(p);
            p += m - 1;
        }
        positions.push(bits - m);
        Self { bits, m, positions }
    }

    /// Number of fixed segment positions (2 for the classic n=8, m≥4 case).
    pub fn segment_count(&self) -> usize {
        self.positions.len()
    }

    /// Segment the operand: returns (segment value, left-shift to restore
    /// weight). Picks the lowest fixed position whose window still contains
    /// the leading one (least truncation).
    #[inline]
    fn segment(&self, v: u64) -> (u64, u32) {
        if v == 0 {
            return (0, 0);
        }
        let n_lead = leading_one(v);
        let need = n_lead.saturating_sub(self.m - 1); // minimal start
        #[allow(clippy::expect_used)]
        let pos = *self
            .positions
            .iter()
            .find(|&&p| p >= need)
            // lint:allow(no-panic): new() builds positions to cover every leading-one index
            .expect("position set covers all leading-one positions");
        debug_assert!(
            self.m >= 1 && self.m <= self.bits && pos < self.bits && pos + self.m <= self.bits,
            "segment window exceeds the operand width"
        );
        ((v >> pos) & ((1u64 << self.m) - 1), pos)
    }
}

impl ApproxMultiplier for Dsm {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Dsm { m: self.m }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        let (sa, sha) = self.segment(a);
        let (sb, shb) = self.segment(b);
        debug_assert!(
            sha + shb <= 2 * (self.bits - self.m),
            "restore shift exceeds the double-width datapath"
        );
        (sa * sb) << (sha + shb)
    }

    /// Monomorphized batch kernel: `self` is concrete here, so the
    /// `#[inline]` segment scan inlines statically and the fixed position
    /// table stays resident across the loop.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            let (sa, sha) = self.segment(x);
            let (sb, shb) = self.segment(y);
            debug_assert!(
                sha + shb <= 2 * (self.bits - self.m),
                "restore shift exceeds the double-width datapath"
            );
            *o = (sa * sb) << (sha + shb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn classic_two_segment_case() {
        // n=8, m=4: positions {0, 3(?), 4} — window always contains the
        // leading one.
        let d = Dsm::new(8, 4);
        for v in 1..256u64 {
            let (seg, sh) = d.segment(v);
            let n = super::leading_one(v);
            assert!(
                sh <= n && n < sh + 4,
                "v={v}: leading one {n} outside window [{sh},{})",
                sh + 4
            );
            assert!(seg >> (n - sh) == 1 || seg >> (n - sh) > 0);
        }
    }

    #[test]
    fn low_segment_exact_for_small_values() {
        let d = Dsm::new(8, 4);
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(d.mul(a, b), a * b);
            }
        }
    }

    #[test]
    fn never_loses_leading_one() {
        // Product of the segment values is never zero for nonzero operands.
        let d = Dsm::new(8, 3);
        for a in 1..256u64 {
            assert!(d.mul(a, a) > 0, "a={a}");
        }
    }

    #[test]
    fn mred_tracks_paper_family() {
        // Table 4: DSM(3)=14.11, DSM(5)=3.02, DSM(7)=2.02. Fixed-position
        // segmentation always dominates leading-one truncation error, so we
        // assert the family band and monotonicity rather than exact values.
        let m3 = mred(&Dsm::new(8, 3));
        let m5 = mred(&Dsm::new(8, 5));
        let m7 = mred(&Dsm::new(8, 7));
        assert!(m3 > m5 && m5 > m7, "{m3} {m5} {m7}");
        // Note: Table 4's DSM rows track DRUM almost exactly (DSM(5)=3.02
        // vs DRUM(5)=3.01), which plain fixed-position truncation cannot
        // reach — our faithful 2/3-segment DSM sits higher (5.5 at m=5),
        // matching the original DSM paper's own error analysis. See
        // EXPERIMENTS.md §Deviations.
        assert!((8.0..20.0).contains(&m3), "DSM(3) {m3:.2} vs paper 14.11");
        assert!((2.0..7.0).contains(&m5), "DSM(5) {m5:.2} vs paper 3.02");
    }
}
