//! Mitchell's logarithmic multiplier (Mitchell, IRE Trans. EC 1962; paper
//! ref [28]) — the classic `log2(1+x) ≈ x` approximation, reproduced here
//! exactly as the paper's Sec. IV-D formulates it:
//!
//! ```text
//!   log2(M_APP) = n_A + n_B + X + Y                       (Eq. 9)
//!   M_APP = 2^(nA+nB) (1 + X + Y)        if X + Y < 1
//!         = 2^(nA+nB+1) (X + Y)          if X + Y ≥ 1     (Eq. 10)
//! ```
//!
//! The fixed-point datapath carries the mantissa sum at full precision
//! (`bits-1` fraction bits per operand), matching a hardware implementation
//! with no mantissa truncation.

use super::{leading_one, narrow_result, ApproxMultiplier, DesignSpec};

/// Mitchell behavioural model.
#[derive(Debug, Clone)]
pub struct Mitchell {
    bits: u32,
}

impl Mitchell {
    /// New Mitchell multiplier of the given width.
    pub fn new(bits: u32) -> Self {
        Self { bits }
    }
}

impl ApproxMultiplier for Mitchell {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Mitchell
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let f = self.bits; // fraction bits of the datapath
        let na = leading_one(a);
        let nb = leading_one(b);
        debug_assert!(na < f && nb < f, "operand exceeds the declared {f}-bit width");
        // X, Y in units of 2^-f.
        let x = ((a - (1 << na)) as u128) << (f - na);
        let y = ((b - (1 << nb)) as u128) << (f - nb);
        let s = x + y;
        let one = 1u128 << f;
        let (mant, shift) = if s < one {
            (one + s, na + nb)
        } else {
            (s, na + nb + 1)
        };
        narrow_result(mant << shift, f)
    }

    /// Monomorphized batch kernel: the datapath width `f` and the fixed
    /// `1.0` constant are hoisted; the loop body is branch + shifts only.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        let f = self.bits;
        debug_assert!(f < u128::BITS, "datapath width exceeds the u128 fixed point");
        let one = 1u128 << f;
        for ((&av, &bv), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = if av == 0 || bv == 0 {
                0
            } else {
                let na = leading_one(av);
                let nb = leading_one(bv);
                debug_assert!(na < f && nb < f, "operand exceeds the declared {f}-bit width");
                let x = ((av - (1 << na)) as u128) << (f - na);
                let y = ((bv - (1 << nb)) as u128) << (f - nb);
                let s = x + y;
                let (mant, shift) = if s < one {
                    (one + s, na + nb)
                } else {
                    (s, na + nb + 1)
                };
                narrow_result(mant << shift, f)
            };
        }
    }

    /// Hand-vectorized lane kernel. Both data-dependent branches of the
    /// scalar kernels go branchless: zero operands are pre-masked
    /// ([`crate::simd`]), and the Eq. 10 carry case `X + Y ≥ 1` becomes a
    /// select — `wrap = (s ≥ 1)` folds the mantissa (`1 + s` vs `s`) and
    /// the extra output shift (`na + nb + wrap`) without branching, so the
    /// lane body is straight-line shifts and adds.
    fn mul_batch_simd(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        use crate::simd;
        let f = self.bits;
        debug_assert!(f < u128::BITS, "datapath width exceeds the u128 fixed point");
        let one = 1u128 << f;
        simd::drive_lanes(
            a,
            b,
            out,
            |xa, xb| {
                let keep = simd::nonzero_flags(xa, xb);
                let xm = simd::mask_zero_to_one(xa);
                let ym = simd::mask_zero_to_one(xb);
                let na = simd::leading_one_lanes(&xm);
                let nb = simd::leading_one_lanes(&ym);
                let mut r = [0u64; simd::LANES];
                for (i, r_i) in r.iter_mut().enumerate() {
                    debug_assert!(na[i] < f && nb[i] < f, "operand exceeds the {f}-bit width");
                    let x = ((xm[i] - (1 << na[i])) as u128) << (f - na[i]);
                    let y = ((ym[i] - (1 << nb[i])) as u128) << (f - nb[i]);
                    let s = x + y;
                    let wrap = (s >= one) as u32;
                    let mant = s + (1 - wrap as u128) * one;
                    *r_i = narrow_result(mant << (na[i] + nb[i] + wrap), f) * keep[i];
                }
                r
            },
            |ta, tb, tout| self.mul_batch(ta, tb, tout),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    #[test]
    fn powers_of_two_exact() {
        let m = Mitchell::new(8);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(m.mul(1 << i, 1 << j), 1u64 << (i + j));
            }
        }
    }

    #[test]
    fn always_underestimates() {
        // Mitchell's error is one-sided: approx <= exact.
        let m = Mitchell::new(8);
        for a in 1..256u64 {
            for b in 1..256u64 {
                assert!(m.mul(a, b) <= a * b, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn mred_matches_paper() {
        // Table 4: Mitchell MRED = 3.76 (8-bit).
        let m = Mitchell::new(8);
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        let mred = 100.0 * s / (255.0 * 255.0);
        assert!((mred - 3.76).abs() < 0.2, "MRED {mred:.2} vs paper 3.76");
    }

    #[test]
    fn max_error_matches_table5() {
        // Table 5: Mitchell 8-bit max error distance = 4096.
        let m = Mitchell::new(8);
        let mut max_ed = 0u64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                max_ed = max_ed.max((a * b) - m.mul(a, b));
            }
        }
        assert!(
            (3500..=4200).contains(&max_ed),
            "max ED {max_ed} vs paper 4096"
        );
    }
}
