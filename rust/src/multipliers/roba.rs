//! RoBA — Rounding-Based Approximate multiplier (Zendegani et al., TVLSI
//! 2017; paper ref [12]).
//!
//! Operands are rounded to the nearest power of two (`A_r`, `B_r`); the
//! product is rewritten so every remaining multiplication involves a power
//! of two (pure shifts):
//!
//! ```text
//!   A×B ≈ A_r·B + A·B_r − A_r·B_r
//! ```

use super::{leading_one, ApproxMultiplier, DesignSpec};

/// RoBA behavioural model.
#[derive(Debug, Clone)]
pub struct Roba {
    bits: u32,
}

impl Roba {
    /// New RoBA of the given width.
    pub fn new(bits: u32) -> Self {
        Self { bits }
    }

    /// Round to the nearest power of two (ties toward the larger, as the
    /// RoBA hardware's `A ≥ 1.5·2^n` comparison does).
    #[inline]
    fn round_pow2(v: u64) -> u64 {
        if v == 0 {
            return 0;
        }
        let n = leading_one(v);
        debug_assert!(n < u64::BITS, "leading-one position exceeds the u64 range");
        let base = 1u64 << n;
        // threshold 1.5·2^n, compared as 2v ≥ 3·2^n to stay in integers
        if 2 * v >= 3 * base {
            base << 1
        } else {
            base
        }
    }
}

impl ApproxMultiplier for Roba {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Roba
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let ar = Self::round_pow2(a);
        let br = Self::round_pow2(b);
        // ar·b + a·br − ar·br; all terms are shifts of b, a, and ar.
        let sum = ar * b + a * br;
        let sub = ar * br;
        sum.saturating_sub(sub)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    #[test]
    fn exact_when_either_is_power_of_two() {
        // If A = A_r the identity collapses to A·B exactly.
        let m = Roba::new(8);
        for i in 0..8 {
            let a = 1u64 << i;
            for b in 1..256u64 {
                assert_eq!(m.mul(a, b), a * b, "a=2^{i} b={b}");
            }
        }
    }

    #[test]
    fn rounding_thresholds() {
        assert_eq!(Roba::round_pow2(5), 4); // 5 < 6
        assert_eq!(Roba::round_pow2(6), 8); // 6 >= 6
        assert_eq!(Roba::round_pow2(191), 128); // < 192
        assert_eq!(Roba::round_pow2(192), 256);
    }

    #[test]
    fn mred_reasonable() {
        // RoBA's published 8-bit MRED is ~3–4%; sanity-bound ours.
        let m = Roba::new(8);
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        let mred = 100.0 * s / (255.0 * 255.0);
        assert!(mred < 6.0, "RoBA MRED {mred:.2} out of family");
    }
}
