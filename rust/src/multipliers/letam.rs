//! LETAM — Low-Energy Truncation-based Approximate Multiplier (Vahdat,
//! Kamal, Afzali-Kusha, Pedram, C&EE 2017; paper ref [17]).
//!
//! Plain dynamic truncation: each operand keeps its `t` most significant
//! bits from the leading one (no unbiasing bit — that is DRUM's addition),
//! the reduced operands feed an exact `t×t` multiplier plus shifts.

use super::{leading_one, ApproxMultiplier, DesignSpec};

/// LETAM(t) behavioural model.
#[derive(Debug, Clone)]
pub struct Letam {
    bits: u32,
    t: u32,
}

impl Letam {
    /// New LETAM with window width `t`.
    pub fn new(bits: u32, t: u32) -> Self {
        assert!(t >= 2 && t <= bits);
        Self { bits, t }
    }

    #[inline]
    fn reduce(&self, v: u64) -> u64 {
        if v == 0 {
            return 0;
        }
        let n = leading_one(v);
        let width = n + 1;
        if width <= self.t {
            v
        } else {
            let shift = width - self.t;
            debug_assert!(shift < self.bits, "truncation shift exceeds the declared width");
            (v >> shift) << shift
        }
    }
}

impl ApproxMultiplier for Letam {
    fn spec(&self) -> DesignSpec {
        DesignSpec::Letam { t: self.t }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        self.reduce(a) * self.reduce(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    #[test]
    fn always_underestimates() {
        let m = Letam::new(8, 4);
        for a in 1..256u64 {
            for b in 1..256u64 {
                assert!(m.mul(a, b) <= a * b);
            }
        }
    }

    #[test]
    fn drum_beats_letam_on_mred() {
        // DRUM's unbiasing bit is its whole point: at equal window width it
        // must improve MRED over plain truncation.
        let letam = Letam::new(8, 4);
        let drum = crate::multipliers::Drum::new(8, 4);
        let mut s_l = 0f64;
        let mut s_d = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s_l += ((letam.mul(a, b) as f64 - e) / e).abs();
                s_d += ((drum.mul(a, b) as f64 - e) / e).abs();
            }
        }
        assert!(s_d < s_l, "DRUM {s_d} should beat LETAM {s_l}");
    }

    #[test]
    fn small_values_exact() {
        let m = Letam::new(8, 4);
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(m.mul(a, b), a * b);
            }
        }
    }
}
