//! Mitchell with approximate leading-one detection (Ansari, Gandhi,
//! Cockburn, Han, IET CDT 2021; paper ref [37]) — "Mitchell_LODII" in
//! Table 4.
//!
//! The fast/low-power LOD variants trade exactness of the leading-one
//! *position* for a shorter critical path: in the inexact variants the
//! position's least-significant bits are derived from coarse group signals
//! and can round the position down within a group of `2^g`. We model
//! `LODII-j` as: `j = 0` → exact LOD (their LODII with full correction);
//! `j > 0` → the reported position is rounded down to a multiple of 2 when
//! the true position is odd and the bit below the leading one is clear
//! (the dominant error case of their group-based detectors).

use super::{leading_one, narrow_result, ApproxMultiplier, DesignSpec};

/// Mitchell_LODII-j behavioural model.
#[derive(Debug, Clone)]
pub struct MitchellLodII {
    bits: u32,
    j: u32,
}

const F: u32 = 20;

impl MitchellLodII {
    /// New model; paper evaluates j ∈ {0, 4}.
    pub fn new(bits: u32, j: u32) -> Self {
        Self { bits, j }
    }

    /// Possibly-inexact LOD.
    #[inline]
    fn lod(&self, v: u64) -> u32 {
        let n = leading_one(v);
        debug_assert!(n < self.bits, "leading-one position exceeds the declared width");
        if self.j == 0 {
            return n;
        }
        // Group-based detector: odd positions whose lower neighbour bit is
        // zero report the even position below (position under-estimation).
        if n % 2 == 1 && n >= 1 && (v >> (n - 1)) & 1 == 0 {
            n - 1
        } else {
            n
        }
    }
}

impl ApproxMultiplier for MitchellLodII {
    fn spec(&self) -> DesignSpec {
        DesignSpec::LodII { j: self.j }
    }
    fn bits(&self) -> u32 {
        self.bits
    }
    #[inline]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let na = self.lod(a);
        let nb = self.lod(b);
        // Mantissa relative to the (possibly wrong) detected position;
        // clamp to < 2 as the datapath width would.
        let mant = |v: u64, n: u32| -> u128 {
            debug_assert!(n < u64::BITS, "detected position exceeds the u64 range");
            let x = (v as u128) << F >> n; // v / 2^n in 2^-F units, in [1,4)
            (x - (1 << F)).min((2u128 << F) - 1) // x-1 clamped to [0,2)
        };
        debug_assert!(
            na < self.bits && nb < self.bits,
            "detected position exceeds the declared width"
        );
        let x = mant(a, na);
        let y = mant(b, nb);
        let s = x + y;
        let one = 1u128 << F;
        let (mantissa, shift) = if s < one {
            (one + s, na + nb)
        } else {
            (s, na + nb + 1)
        };
        narrow_result(mantissa << shift, F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multipliers::ApproxMultiplier;

    fn mred(m: &dyn ApproxMultiplier) -> f64 {
        let mut s = 0f64;
        for a in 1..256u64 {
            for b in 1..256u64 {
                let e = (a * b) as f64;
                s += ((m.mul(a, b) as f64 - e) / e).abs();
            }
        }
        100.0 * s / (255.0 * 255.0)
    }

    #[test]
    fn j0_equals_plain_mitchell() {
        let lodii = MitchellLodII::new(8, 0);
        let mitchell = crate::multipliers::Mitchell::new(8);
        for a in 1..256u64 {
            for b in 1..256u64 {
                assert_eq!(lodii.mul(a, b), mitchell.mul(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn inexact_lod_slightly_worse() {
        // Table 4: LODII_0 3.81 vs LODII_4 4.12 — small, consistent gap.
        let m0 = mred(&MitchellLodII::new(8, 0));
        let m4 = mred(&MitchellLodII::new(8, 4));
        assert!(m4 > m0, "j=4 {m4:.2} should be worse than j=0 {m0:.2}");
        assert!(m4 - m0 < 1.5, "gap {:.2} too large", m4 - m0);
    }
}
