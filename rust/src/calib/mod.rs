//! The unified calibration plane — design-time constants as a first-class,
//! pluggable, persistent subsystem.
//!
//! The paper's core contribution is *calibration*: the zero-intercept curve
//! fit (α, ΔEE) and the M-segment error-averaged compensation LUT
//! (Sec. III, Figs. 5–7, Table 7). This module owns that plane end to end:
//!
//! 1. **Strategies** — a [`Calibrator`] trait with four selectable
//!    backends: the paper's exhaustive scan, the closed-form analytic
//!    statistics, a fixed-seed sampled estimator for wide operand spaces,
//!    and a quantile (error-mass-weighted) segmentation alternative to
//!    the paper's uniform S-segments (`scaleTRIM-Q`). Strategy choice is
//!    an accuracy-vs-calibration-cost axis that flows into
//!    [`DesignSpec`](crate::multipliers::DesignSpec), the DSE objectives
//!    ([`DesignPoint::mared_calib_cost`](crate::dse::DesignPoint::mared_calib_cost))
//!    and the `repro --exp calib` report.
//! 2. **Cache** — one process-wide [`CalibCache`] keyed by
//!    `(DesignSpec, bits, strategy, kind)` replaces the three ad-hoc
//!    `Mutex<Option<HashMap>>` statics the system grew (`lut::cached_params`,
//!    `PiecewiseLinear`'s private fit cache, `nn::cached_lut`). Per-key
//!    `OnceLock` slots make a panicking calibration a retryable event, not
//!    a poisoned static.
//! 3. **Store** — a versioned, checksummed on-disk artifact bundle
//!    ([`CalibStore`], `scaletrim calib export`), loaded back bit-for-bit
//!    on warm start. With `SCALETRIM_ARTIFACTS` pointing at an exported
//!    set, a 16-bit cold start becomes a file read, and the serving
//!    coordinator's lanes come up on warm constants — every acquisition
//!    routes through the self-seeding cache.

mod cache;
mod store;
mod strategy;

pub use cache::{ArtifactKind, CacheStats, CalibCache, CalibKey, CalibValue};
pub use store::{
    default_export_entries, CalibStore, StoreEntry, STORE_FILE, STORE_FORMAT, STORE_VERSION,
};
pub use strategy::{
    calibrator, fit_piecewise, CalibStrategy, Calibrator, SAMPLED_OPERANDS, SAMPLED_SEED,
};
pub(crate) use strategy::fit_uniform;

use crate::obs::names::metric;
use std::sync::OnceLock;

/// The process-wide calibration cache.
///
/// On first access, if `SCALETRIM_ARTIFACTS` is set, the standard store
/// location (`$SCALETRIM_ARTIFACTS/calib`) is loaded into the cache so the
/// whole process runs on the warm path (CI exercises exactly this). A
/// rejected bundle (wrong version, bad checksum, invalid constants) is
/// reported on stderr and ignored — the cache then calibrates cold, which
/// is always correct.
pub fn cache() -> &'static CalibCache {
    static GLOBAL: OnceLock<CalibCache> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let c = CalibCache::new();
        // Env-gated only: without the explicit override, plain library use
        // performs no filesystem discovery — warm starts are an opt-in,
        // never a side effect of a bundle lying around the filesystem.
        if std::env::var_os("SCALETRIM_ARTIFACTS").is_some() {
            load_default_store(&c);
        }
        c
    })
}

/// Load the standard store location into a cache (best-effort): a missing
/// bundle is 0 entries, a rejected one is a stderr warning — cold
/// calibration is always a correct fallback. Returns the seeded count.
fn load_default_store(c: &CalibCache) -> usize {
    let Some(s) = CalibStore::discover() else {
        return 0;
    };
    match s.load_if_present() {
        Ok(Some(entries)) => c.warm(entries.into_iter().map(|e| (e.key, e.value))),
        Ok(None) => 0,
        Err(e) => {
            eprintln!(
                "warning: ignoring calibration artifact store at {}: {e:#}",
                s.path().display()
            );
            0
        }
    }
}

/// Publish the process-wide cache's counters as gauges on the
/// [`crate::obs`] registry (pull-style bridge: the cache keeps its own
/// atomics on the hot path; call this before snapshotting — `scaletrim
/// obs`, `--metrics-out` and `repro --exp obs` do).
pub fn publish_obs() {
    let s = cache().stats();
    let r = crate::obs::registry();
    r.gauge(metric::CALIB_CACHE_ENTRIES, &[]).set(s.entries as i64);
    r.gauge(metric::CALIB_CACHE_HITS, &[]).set(s.hits as i64);
    r.gauge(metric::CALIB_CACHE_MISSES, &[]).set(s.misses as i64);
    r.gauge(metric::CALIB_CACHE_WARM_LOADED, &[]).set(s.warm_loaded as i64);
    r.gauge(metric::CALIB_CACHE_INIT_RETRIES, &[]).set(s.retries() as i64);
    r.gauge(metric::CALIB_CACHE_RESIDENT_BYTES, &[]).set(s.resident_bytes as i64);
    r.gauge(metric::CALIB_CACHE_DEDICATED_BYTES, &[]).set(s.dedicated_bytes as i64);
}

/// Explicit warm start: make sure the process-wide cache is initialized
/// (which, under the `SCALETRIM_ARTIFACTS` opt-in, loads the artifact
/// bundle) and report how many entries came from disk. Strictly
/// env-gated — without the explicit override this performs **no**
/// filesystem discovery, so a stale `./artifacts/calib` bundle lying
/// around a repo can never silently replace fresh calibration. Memoized;
/// used by `scaletrim calib warm` and anything that wants the load to
/// happen eagerly rather than at the first calibration.
pub fn warm_start() -> usize {
    static WARMED: OnceLock<usize> = OnceLock::new();
    *WARMED.get_or_init(|| {
        if std::env::var_os("SCALETRIM_ARTIFACTS").is_none() {
            return 0;
        }
        // `cache()` init performs the load under the env opt-in; report
        // its count without re-parsing the bundle.
        cache().stats().warm_loaded as usize
    })
}
