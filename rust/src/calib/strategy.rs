//! Calibration strategies — pluggable ways of producing the scaleTRIM
//! design-time constants (α, ΔEE, C_i) and the piecewise-linear fits.
//!
//! The paper has exactly one calibration procedure: an exhaustive operand
//! scan followed by uniform S-segmentation (Sec. III-A/B). This module makes
//! that one point on an accuracy-vs-calibration-cost axis:
//!
//! - [`CalibStrategy::Exhaustive`] — the paper's procedure, via the exact
//!   truncation-class decomposition (`lut::calibrate`): O(2^bits) scan.
//! - [`CalibStrategy::Analytic`] — closed-form class statistics
//!   (`lut::calibrate_analytic`): O(bits·2^h), bit-comparable constants at
//!   8/16 bits and the only practical option at 32+.
//! - [`CalibStrategy::Sampled`] — Monte-Carlo class statistics from a
//!   fixed-seed operand sample: cheap and width-independent, at the cost of
//!   approximate constants (no paper-fidelity claim).
//! - [`CalibStrategy::Quantile`] — keeps the exact statistics but replaces
//!   the paper's *uniform* S-segments with error-mass-weighted boundaries:
//!   segment edges are placed at quantiles of the absolute residual mass
//!   |Σ EV(s)| over the truncated-sum space, so segments concentrate where
//!   the linearization error lives. The resulting design is
//!   [`DesignSpec::ScaleTrimQ`](crate::multipliers::DesignSpec) — distinct
//!   hardware (boundary comparators instead of MSB indexing), distinct
//!   identity.
//!
//! Every strategy is deterministic (fixed seeds), so calibration artifacts
//! round-trip bit-for-bit through the artifact store
//! ([`CalibStore`](super::CalibStore)).

use crate::lut::{
    analytic_classes, calibrate, calibrate_analytic, OperandClasses, ScaleTrimParams,
    COMP_FRAC_BITS,
};
use crate::util::rng::Xoshiro256;
use std::fmt;
use std::str::FromStr;

/// Operand samples drawn per calibration by [`CalibStrategy::Sampled`].
pub const SAMPLED_OPERANDS: u64 = 1 << 15;

/// Fixed seed for [`CalibStrategy::Sampled`] — part of the strategy's
/// identity: two processes calibrating the same key must agree bit-for-bit
/// (the artifact store pins this).
pub const SAMPLED_SEED: u64 = 0x5CA1E_CA11B;

/// Selectable calibration strategy — the third component of every
/// [`CalibKey`](super::CalibKey).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CalibStrategy {
    /// Exact full-space scan (the paper's procedure; O(2^bits)).
    Exhaustive,
    /// Exact closed-form class statistics (O(bits·2^h); any width).
    Analytic,
    /// Fixed-seed Monte-Carlo class statistics (O(samples); approximate).
    Sampled,
    /// Exact statistics + error-mass-weighted segment boundaries
    /// (the `scaleTRIM-Q` design family).
    Quantile,
    /// Externally supplied constants (`ScaleTrim::with_params` — paper
    /// Table 7 replays, artifact experiments). Not a calibrator: there is
    /// nothing to recompute, so [`calibrator`] rejects it — but it *is* a
    /// cache identity, which keeps external-constant instances out of the
    /// strategy-keyed product-LUT slots the self-calibrated configs share.
    External,
}

impl CalibStrategy {
    /// Every *calibratable* strategy, in cost order ([`External`]
    /// (CalibStrategy::External) is an identity tag, not a backend).
    pub const ALL: [CalibStrategy; 4] = [
        CalibStrategy::Exhaustive,
        CalibStrategy::Analytic,
        CalibStrategy::Sampled,
        CalibStrategy::Quantile,
    ];

    /// Stable lower-case tag (artifact files, CLI, cache keys on the wire).
    pub fn as_str(&self) -> &'static str {
        match self {
            CalibStrategy::Exhaustive => "exhaustive",
            CalibStrategy::Analytic => "analytic",
            CalibStrategy::Sampled => "sampled",
            CalibStrategy::Quantile => "quantile",
            CalibStrategy::External => "external",
        }
    }
}

impl fmt::Display for CalibStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for CalibStrategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim() {
            "exhaustive" => Ok(CalibStrategy::Exhaustive),
            "analytic" => Ok(CalibStrategy::Analytic),
            "sampled" => Ok(CalibStrategy::Sampled),
            "quantile" => Ok(CalibStrategy::Quantile),
            "external" => Ok(CalibStrategy::External),
            other => Err(format!(
                "unknown calibration strategy {other:?} \
                 (known: exhaustive, analytic, sampled, quantile, external)"
            )),
        }
    }
}

/// A calibration backend: turns `(bits, h, M)` into scaleTRIM constants.
///
/// Implementations must be deterministic — same inputs, bit-identical
/// [`ScaleTrimParams`] — because the artifact store pins warm-start loads
/// against fresh calibration. Panics on parameters outside the strategy's
/// domain (the typed gate is
/// [`DesignSpec::validate`](crate::multipliers::DesignSpec::validate),
/// which every constructor routes through before reaching a calibrator).
pub trait Calibrator: Send + Sync {
    /// Which strategy this backend implements.
    fn strategy(&self) -> CalibStrategy;

    /// Produce the scaleTRIM(h, M) constants at the given operand width.
    fn calibrate(&self, bits: u32, h: u32, m: u32) -> ScaleTrimParams;

    /// Rough cold-calibration cost in datapath-equivalent operations —
    /// the DSE's calibration-cost objective
    /// ([`DesignPoint::mared_calib_cost`](crate::dse::DesignPoint::mared_calib_cost)).
    fn cost_ops(&self, bits: u32, h: u32) -> f64;

    /// Whether the strategy claims the paper's Table 4/7 anchors (exact
    /// statistics + the paper's segmentation). Anchor tests gate on this.
    fn paper_fidelity(&self) -> bool;
}

/// Resolve the backend for a strategy (stateless singletons). Panics on
/// [`CalibStrategy::External`] — external constants are an identity, not a
/// recomputable calibration (guarded upstream: `ScaleTrim::with_strategy`
/// rejects it as a typed error).
pub fn calibrator(s: CalibStrategy) -> &'static dyn Calibrator {
    match s {
        CalibStrategy::Exhaustive => &ExhaustiveCalibrator,
        CalibStrategy::Analytic => &AnalyticCalibrator,
        CalibStrategy::Sampled => &SampledCalibrator,
        CalibStrategy::Quantile => &QuantileCalibrator,
        CalibStrategy::External => {
            // lint:allow(no-panic): External params never calibrate — reaching here is a caller bug
            panic!("external constants have no calibrator — they arrive via with_params")
        }
    }
}

/// The paper's procedure: exact class statistics from a full operand scan,
/// uniform segmentation ([`crate::lut::calibrate`]).
pub struct ExhaustiveCalibrator;

impl Calibrator for ExhaustiveCalibrator {
    fn strategy(&self) -> CalibStrategy {
        CalibStrategy::Exhaustive
    }
    fn calibrate(&self, bits: u32, h: u32, m: u32) -> ScaleTrimParams {
        calibrate(bits, h, m)
    }
    fn cost_ops(&self, bits: u32, h: u32) -> f64 {
        (1u64 << bits) as f64 + 4f64.powi(h as i32)
    }
    fn paper_fidelity(&self) -> bool {
        true
    }
}

/// Closed-form class statistics ([`crate::lut::calibrate_analytic`]) —
/// exact at every width, O(bits·2^h).
pub struct AnalyticCalibrator;

impl Calibrator for AnalyticCalibrator {
    fn strategy(&self) -> CalibStrategy {
        CalibStrategy::Analytic
    }
    fn calibrate(&self, bits: u32, h: u32, m: u32) -> ScaleTrimParams {
        calibrate_analytic(bits, h, m)
    }
    fn cost_ops(&self, bits: u32, h: u32) -> f64 {
        (bits as f64) * (1u64 << h) as f64 + 4f64.powi(h as i32)
    }
    fn paper_fidelity(&self) -> bool {
        true
    }
}

/// Fixed-seed Monte-Carlo class statistics: `SAMPLED_OPERANDS` draws per
/// calibration regardless of width — the cheap option for 16/24-bit spaces
/// when the closed form is not trusted and a full scan is not affordable.
pub struct SampledCalibrator;

impl Calibrator for SampledCalibrator {
    fn strategy(&self) -> CalibStrategy {
        CalibStrategy::Sampled
    }
    fn calibrate(&self, bits: u32, h: u32, m: u32) -> ScaleTrimParams {
        let (count, sum_x) = sampled_classes(bits, h, SAMPLED_OPERANDS, SAMPLED_SEED);
        fit_params(bits, h, m, &count, &sum_x, Vec::new())
    }
    fn cost_ops(&self, _bits: u32, h: u32) -> f64 {
        // One class-accumulate per drawn operand, plus the pair loop.
        SAMPLED_OPERANDS as f64 + 4f64.powi(h as i32)
    }
    fn paper_fidelity(&self) -> bool {
        false
    }
}

/// Exact (closed-form) statistics with error-mass-weighted segment
/// boundaries: the `scaleTRIM-Q` alternative to the paper's uniform
/// S-segments. Boundaries land at equal quantiles of the absolute residual
/// mass, so compensation resolution goes where the linearization error is.
pub struct QuantileCalibrator;

impl Calibrator for QuantileCalibrator {
    fn strategy(&self) -> CalibStrategy {
        CalibStrategy::Quantile
    }
    fn calibrate(&self, bits: u32, h: u32, m: u32) -> ScaleTrimParams {
        let (count, sum_x) = analytic_classes(bits, h);
        if m < 2 {
            // Degenerate: nothing to segment — identical to the uniform fit.
            return fit_params(bits, h, m, &count, &sum_x, Vec::new());
        }
        let core = fit_core(h, &count, &sum_x, true);
        let bounds = quantile_bounds(&core.ev_sum, m);
        assemble(bits, h, m, &core, bounds)
    }
    fn cost_ops(&self, bits: u32, h: u32) -> f64 {
        // Analytic statistics + one extra pass over the 2^(h+1) sums.
        (bits as f64) * (1u64 << h) as f64 + 4f64.powi(h as i32) + (1u64 << (h + 1)) as f64
    }
    fn paper_fidelity(&self) -> bool {
        false
    }
}

/// Monte-Carlo per-class statistics: `samples` operands drawn uniformly
/// from `[1, 2^bits)` with a fixed seed (deterministic by construction).
fn sampled_classes(bits: u32, h: u32, samples: u64, seed: u64) -> (Vec<f64>, Vec<f64>) {
    use crate::multipliers::{leading_one, truncate_fraction};
    let classes = 1usize << h;
    let mut count = vec![0f64; classes];
    let mut sum_x = vec![0f64; classes];
    let mut rng = Xoshiro256::seed_from_u64(seed);
    for _ in 0..samples {
        let a = rng.gen_operand(bits);
        let n = leading_one(a);
        let x = a as f64 / (1u64 << n) as f64 - 1.0;
        let u = truncate_fraction(a, n, h) as usize;
        count[u] += 1.0;
        sum_x[u] += x;
    }
    (count, sum_x)
}

/// Zero-intercept α fit over all truncation-class pairs — the same math as
/// `lut::calibrate`, over caller-supplied class statistics.
fn alpha_fit(h: u32, count: &[f64], sum_x: &[f64]) -> f64 {
    let classes = 1usize << h;
    let scale = (1u64 << h) as f64;
    let mut sum_ts = 0f64;
    let mut sum_ss = 0f64;
    for u in 0..classes {
        let (nu, sxu) = (count[u], sum_x[u]);
        if nu == 0.0 {
            continue;
        }
        for v in 0..classes {
            let (nv, sxv) = (count[v], sum_x[v]);
            if nv == 0.0 {
                continue;
            }
            let s = (u + v) as f64 / scale;
            let sum_t = nv * sxu + nu * sxv + sxu * sxv;
            sum_ts += s * sum_t;
            sum_ss += s * s * nu * nv;
        }
    }
    sum_ts / sum_ss
}

/// Per-truncated-sum residual profile: for every `s_int ∈ [0, 2^(h+1)−1)`,
/// the pair mass `w[s] = Σ n_u·n_v` and the summed Error Value
/// `ev_sum[s] = Σ (t − gain·s)` over class pairs with `u + v = s`.
fn space_profile(h: u32, count: &[f64], sum_x: &[f64], gain: f64) -> (Vec<f64>, Vec<f64>) {
    let classes = 1usize << h;
    let scale = (1u64 << h) as f64;
    let len = 2 * classes - 1;
    let mut w = vec![0f64; len];
    let mut ev_sum = vec![0f64; len];
    for u in 0..classes {
        let (nu, sxu) = (count[u], sum_x[u]);
        if nu == 0.0 {
            continue;
        }
        for v in 0..classes {
            let (nv, sxv) = (count[v], sum_x[v]);
            if nv == 0.0 {
                continue;
            }
            let s_int = u + v;
            let s = s_int as f64 / scale;
            let sum_t = nv * sxu + nu * sxv + sxu * sxv;
            w[s_int] += nu * nv;
            ev_sum[s_int] += sum_t - gain * s * nu * nv;
        }
    }
    (w, ev_sum)
}

/// Place `m − 1` strictly-increasing segment boundaries at equal quantiles
/// of the absolute residual mass `|ev_sum[s]|`. Boundaries may run past the
/// populated range when `m` exceeds the number of mass-bearing sums — the
/// trailing segments are then empty (`C_i = 0`) and never selected.
fn quantile_bounds(ev_sum: &[f64], m: u32) -> Vec<u64> {
    debug_assert!(m >= 2);
    let total: f64 = ev_sum.iter().map(|e| e.abs()).sum();
    let mut bounds: Vec<u64> = Vec::with_capacity(m as usize - 1);
    if total > 0.0 {
        let mut cum = 0f64;
        let mut k = 1u32;
        for (s, e) in ev_sum.iter().enumerate() {
            cum += e.abs();
            while k < m && cum >= total * k as f64 / m as f64 - 1e-12 {
                let cand = (s as u64 + 1).max(bounds.last().map_or(1, |&b| b + 1));
                bounds.push(cand);
                k += 1;
            }
            if k >= m {
                break;
            }
        }
    }
    // Degenerate profiles (all-zero mass, or fewer sums than segments):
    // pad with strictly-increasing out-of-range boundaries (the trailing
    // segments stay empty and unselected).
    while bounds.len() < m as usize - 1 {
        let floor = ev_sum.len() as u64;
        let next = bounds.last().map_or(floor, |&b| (b + 1).max(floor));
        bounds.push(next);
    }
    bounds
}

/// The segmentation-independent half of a calibration: the α fit, its
/// power-of-two quantisation, and (when segments will be fitted) the
/// per-truncated-sum residual profile.
struct FitCore {
    alpha: f64,
    delta_ee: i32,
    /// Pair mass per `s_int` (empty when the profile was skipped).
    w: Vec<f64>,
    /// Summed Error Value per `s_int` (empty when the profile was skipped).
    ev_sum: Vec<f64>,
}

fn fit_core(h: u32, count: &[f64], sum_x: &[f64], with_profile: bool) -> FitCore {
    let alpha = alpha_fit(h, count, sum_x);
    let delta_ee = (alpha - 1.0).log2().floor() as i32;
    let (w, ev_sum) = if with_profile {
        let gain = 1.0 + (delta_ee as f64).exp2();
        space_profile(h, count, sum_x, gain)
    } else {
        // Linearization-only (M = 0): the residual pair-loop would be
        // discarded — skip the whole second pass.
        (Vec::new(), Vec::new())
    };
    FitCore {
        alpha,
        delta_ee,
        w,
        ev_sum,
    }
}

/// Uniform-segmentation fit over caller-supplied class statistics — the
/// single copy of the paper's fit + averaging math. The reference entry
/// points [`crate::lut::calibrate`] (scan statistics) and
/// [`crate::lut::calibrate_analytic`] (closed-form statistics) both route
/// here, as do the sampled backend and (via explicit bounds) the quantile
/// backend: only the *class-statistics producer* differs per path.
pub(crate) fn fit_uniform(
    bits: u32,
    h: u32,
    m: u32,
    count: &[f64],
    sum_x: &[f64],
) -> ScaleTrimParams {
    fit_params(bits, h, m, count, sum_x, Vec::new())
}

/// [`fit_uniform`] with optional explicit segment boundaries (`bounds`
/// empty means the paper's uniform split).
fn fit_params(
    bits: u32,
    h: u32,
    m: u32,
    count: &[f64],
    sum_x: &[f64],
    bounds: Vec<u64>,
) -> ScaleTrimParams {
    let core = fit_core(h, count, sum_x, m > 0);
    assemble(bits, h, m, &core, bounds)
}

/// Average the residual per segment (uniform split when `bounds` is empty,
/// the supplied boundaries otherwise) and assemble validated params. The
/// segment mapping is [`crate::lut`]'s `segment_of` — the same function
/// the datapath selects with, so calibration-time averaging and hardware
/// segment selection cannot drift apart.
fn assemble(bits: u32, h: u32, m: u32, core: &FitCore, bounds: Vec<u64>) -> ScaleTrimParams {
    let (c, c_fixed) = if m == 0 {
        (Vec::new(), Vec::new())
    } else {
        let mut err_sum = vec![0f64; m as usize];
        let mut err_cnt = vec![0f64; m as usize];
        for (s_int, (&ws, &es)) in core.w.iter().zip(core.ev_sum.iter()).enumerate() {
            if ws == 0.0 {
                continue;
            }
            let seg = crate::lut::segment_of(s_int as u64, m, h, &bounds);
            err_sum[seg] += es;
            err_cnt[seg] += ws;
        }
        let c: Vec<f64> = err_sum
            .iter()
            .zip(&err_cnt)
            .map(|(&e, &n)| if n > 0.0 { e / n } else { 0.0 })
            .collect();
        let q = (1u64 << COMP_FRAC_BITS) as f64;
        let c_fixed = c.iter().map(|&x| (x * q).round() as i64).collect();
        (c, c_fixed)
    };
    let params = ScaleTrimParams {
        bits,
        h,
        m,
        alpha: core.alpha,
        delta_ee: core.delta_ee,
        c,
        c_fixed,
        seg_bounds: if m == 0 { Vec::new() } else { bounds },
    };
    params.validate();
    params
}

/// Offline per-segment least-squares fit of `t = X+Y+XY` on `s = X_h+Y_h`
/// for the piecewise-linear baseline (Sec. IV-D) — the pure computation
/// behind [`PiecewiseLinear`](crate::multipliers::PiecewiseLinear); the
/// process-wide copy lives in the [`CalibCache`](super::CalibCache).
pub fn fit_piecewise(bits: u32, h: u32, segments: u32) -> Vec<(i64, i64)> {
    let f = crate::multipliers::piecewise::PIECEWISE_FRAC_BITS;
    let cls = OperandClasses::scan(bits, h);
    let classes = 1usize << h;
    let scale = (1u64 << h) as f64;
    // Per-segment normal-equation sums for t ~ α s + β.
    let m = segments as usize;
    let (mut sw, mut ss, mut sss, mut st, mut sst) =
        (vec![0f64; m], vec![0f64; m], vec![0f64; m], vec![0f64; m], vec![0f64; m]);
    for u in 0..classes {
        let (nu, sxu) = (cls.count[u] as f64, cls.sum_x[u]);
        if nu == 0.0 {
            continue;
        }
        for v in 0..classes {
            let (nv, sxv) = (cls.count[v] as f64, cls.sum_x[v]);
            if nv == 0.0 {
                continue;
            }
            let s_int = (u + v) as u64;
            let s = s_int as f64 / scale;
            let seg = crate::lut::segment_of(s_int, segments, h, &[]);
            let wgt = nu * nv;
            let sum_t = nv * sxu + nu * sxv + sxu * sxv;
            sw[seg] += wgt;
            ss[seg] += wgt * s;
            sss[seg] += wgt * s * s;
            st[seg] += sum_t;
            sst[seg] += s * sum_t;
        }
    }
    (0..m)
        .map(|i| {
            let det = sw[i] * sss[i] - ss[i] * ss[i];
            let (alpha, beta) = if det.abs() < 1e-12 {
                // Degenerate segment (single s value): constant fit.
                (0.0, if sw[i] > 0.0 { st[i] / sw[i] } else { 0.0 })
            } else {
                let alpha = (sw[i] * sst[i] - ss[i] * st[i]) / det;
                let beta = (sss[i] * st[i] - ss[i] * sst[i]) / det;
                (alpha, beta)
            };
            let q = (1u64 << f) as f64;
            ((alpha * q).round() as i64, (beta * q).round() as i64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_tags_round_trip() {
        for s in CalibStrategy::ALL {
            assert_eq!(s.as_str().parse::<CalibStrategy>().unwrap(), s);
            assert_eq!(calibrator(s).strategy(), s);
        }
        // The external tag round-trips but is not a backend.
        assert_eq!(
            "external".parse::<CalibStrategy>().unwrap(),
            CalibStrategy::External
        );
        assert!("warp".parse::<CalibStrategy>().is_err());
    }

    #[test]
    #[should_panic(expected = "no calibrator")]
    fn external_has_no_calibrator() {
        let _ = calibrator(CalibStrategy::External);
    }

    /// The factored fit over *exhaustive-scan* statistics must reproduce
    /// `lut::calibrate`: α bit-for-bit (same accumulation order), the
    /// segment constants to within re-association noise (the factored
    /// path pre-aggregates per truncated sum, which reorders the float
    /// additions), and the 16-bit datapath constants exactly.
    #[test]
    fn factored_fit_matches_reference_calibration() {
        for (h, m) in [(3u32, 0u32), (3, 4), (4, 8)] {
            let cls = OperandClasses::scan(8, h);
            let count: Vec<f64> = cls.count.iter().map(|&c| c as f64).collect();
            let ours = fit_params(8, h, m, &count, &cls.sum_x, Vec::new());
            let reference = calibrate(8, h, m);
            assert_eq!(ours.alpha.to_bits(), reference.alpha.to_bits(), "h={h} m={m}");
            assert_eq!(ours.delta_ee, reference.delta_ee);
            assert_eq!(ours.c_fixed, reference.c_fixed, "h={h} m={m}");
            for (a, b) in ours.c.iter().zip(&reference.c) {
                assert!((a - b).abs() < 1e-9, "h={h} m={m}: C {a} vs {b}");
            }
        }
    }

    #[test]
    fn sampled_close_to_exact() {
        let exact = calibrate(8, 3, 4);
        let sampled = calibrator(CalibStrategy::Sampled).calibrate(8, 3, 4);
        assert!(
            (exact.alpha - sampled.alpha).abs() < 0.02,
            "sampled alpha {} vs exact {}",
            sampled.alpha,
            exact.alpha
        );
        assert_eq!(exact.delta_ee, sampled.delta_ee);
        for (a, b) in exact.c.iter().zip(&sampled.c) {
            assert!((a - b).abs() < 0.05, "C drift: {a} vs {b}");
        }
    }

    #[test]
    fn sampled_is_deterministic() {
        let a = calibrator(CalibStrategy::Sampled).calibrate(16, 5, 8);
        let b = calibrator(CalibStrategy::Sampled).calibrate(16, 5, 8);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        assert_eq!(a.c_fixed, b.c_fixed);
    }

    #[test]
    fn quantile_bounds_are_strictly_increasing_and_sized() {
        let p = calibrator(CalibStrategy::Quantile).calibrate(8, 4, 8);
        assert_eq!(p.seg_bounds.len(), 7);
        for w in p.seg_bounds.windows(2) {
            assert!(w[0] < w[1], "bounds not strictly increasing: {:?}", p.seg_bounds);
        }
        assert_eq!(p.c.len(), 8);
        // The α fit is segmentation-independent: identical to analytic.
        let uniform = calibrate_analytic(8, 4, 8);
        assert_eq!(p.alpha.to_bits(), uniform.alpha.to_bits());
        assert_eq!(p.delta_ee, uniform.delta_ee);
    }

    #[test]
    fn quantile_segment_lookup_covers_all_segments_in_range() {
        let p = calibrator(CalibStrategy::Quantile).calibrate(8, 3, 4);
        let max_s = (1u64 << 4) - 2; // 2^(h+1) − 2
        let mut seen = vec![false; 4];
        for s in 0..=max_s {
            let seg = p.segment(s);
            assert!(seg < 4);
            seen[seg] = true;
        }
        // At least the first segments must be reachable (trailing ones may
        // be empty on degenerate profiles, never on the real 8-bit one).
        assert!(seen[0] && seen[1], "segments unreachable: {seen:?}");
    }

    #[test]
    fn cost_ordering_is_sane() {
        let h = 5u32;
        let ex = calibrator(CalibStrategy::Exhaustive).cost_ops(16, h);
        let an = calibrator(CalibStrategy::Analytic).cost_ops(16, h);
        let sa = calibrator(CalibStrategy::Sampled).cost_ops(16, h);
        assert!(an < ex, "analytic must be cheaper than a 16-bit scan");
        assert!(sa < ex);
        // Paper fidelity: exact statistics + paper segmentation only.
        assert!(calibrator(CalibStrategy::Exhaustive).paper_fidelity());
        assert!(calibrator(CalibStrategy::Analytic).paper_fidelity());
        assert!(!calibrator(CalibStrategy::Sampled).paper_fidelity());
        assert!(!calibrator(CalibStrategy::Quantile).paper_fidelity());
    }

    #[test]
    fn fit_piecewise_matches_expected_shape() {
        let coef = fit_piecewise(8, 4, 4);
        assert_eq!(coef.len(), 4);
        // α_s near the global fit (~1.3·2^24) for interior segments.
        let q = (1u64 << crate::multipliers::piecewise::PIECEWISE_FRAC_BITS) as f64;
        for &(a, _) in &coef[1..3] {
            let a = a as f64 / q;
            assert!(a > 0.5 && a < 2.5, "per-segment alpha {a} out of family");
        }
    }
}
