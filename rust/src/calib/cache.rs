//! The process-wide calibration cache — one typed, poison-safe home for
//! every design-time artifact the system used to stash in ad-hoc statics.
//!
//! Before this plane existed, three independent `Mutex<Option<HashMap>>`
//! statics held calibration state with three different key shapes:
//! `lut::cached_params` (`(bits, h, m)`), `PiecewiseLinear`'s private
//! `cached_fit` (`(bits, h, segments)`), and `nn::cached_lut`
//! (`(DesignSpec, bits)`). One panicking calibration poisoned its static
//! and killed every later user of that width. [`CalibCache`] replaces all
//! three with a single map keyed by [`CalibKey`] — the typed
//! `(DesignSpec, bits, strategy, kind)` identity — and two poisoning
//! defenses:
//!
//! - the registry `Mutex` is held only for map bookkeeping (no user code
//!   runs under it) and recovers from poisoning on every acquisition;
//! - each entry is its own [`OnceLock`]: a calibration that panics leaves
//!   *that slot* uninitialized (the next caller simply retries) and cannot
//!   poison any other key.
//!
//! The cache also back-ends the warm-start path: the on-disk
//! [store](super::store) is loaded into it via [`CalibCache::warm`], making
//! a 16-bit cold start a file read.

use super::strategy::{calibrator, fit_piecewise, CalibStrategy};
use crate::lut::ScaleTrimParams;
use crate::multipliers::{ApproxMultiplier, DesignSpec};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

/// What kind of design-time artifact a cache entry holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// scaleTRIM constants (α, ΔEE, C_i, segment boundaries).
    ScaleTrimParams,
    /// Piecewise-linear per-segment (α_s, β_s) coefficients.
    PiecewiseFit,
    /// 256×256 signed product LUT (derived; never persisted).
    ProductLut,
}

impl ArtifactKind {
    /// Stable tag (artifact files).
    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::ScaleTrimParams => "scaletrim-params",
            ArtifactKind::PiecewiseFit => "piecewise-fit",
            ArtifactKind::ProductLut => "product-lut",
        }
    }

    /// Parse the stable tag back.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "scaletrim-params" => Ok(ArtifactKind::ScaleTrimParams),
            "piecewise-fit" => Ok(ArtifactKind::PiecewiseFit),
            "product-lut" => Ok(ArtifactKind::ProductLut),
            other => Err(format!("unknown artifact kind {other:?}")),
        }
    }
}

/// The unified cache key: typed config identity + operand width +
/// calibration strategy + artifact kind. Strategy is part of the key
/// because a sampled calibration of the same `(spec, bits)` is *not* the
/// exhaustive one — keying them apart is what makes strategy selection
/// safe to thread through shared caches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CalibKey {
    /// Typed configuration identity.
    pub spec: DesignSpec,
    /// Operand width the artifact was calibrated at.
    pub bits: u32,
    /// Strategy that produced (or would produce) the artifact.
    pub strategy: CalibStrategy,
    /// Artifact kind.
    pub kind: ArtifactKind,
}

/// A cached calibration artifact. `Arc`'d so handles are cheap and the
/// cache, the instances and the artifact store share one allocation.
#[derive(Debug, Clone)]
pub enum CalibValue {
    /// scaleTRIM constants.
    ScaleTrim(Arc<ScaleTrimParams>),
    /// Piecewise-linear coefficients.
    Piecewise(Arc<Vec<(i64, i64)>>),
    /// Signed product LUT.
    ProductLut(Arc<Vec<i32>>),
}

impl CalibValue {
    /// The artifact kind this value satisfies.
    pub fn kind(&self) -> ArtifactKind {
        match self {
            CalibValue::ScaleTrim(_) => ArtifactKind::ScaleTrimParams,
            CalibValue::Piecewise(_) => ArtifactKind::PiecewiseFit,
            CalibValue::ProductLut(_) => ArtifactKind::ProductLut,
        }
    }

    /// Resident bytes (payload only, for the sharing statistics).
    pub fn resident_bytes(&self) -> usize {
        match self {
            CalibValue::ScaleTrim(p) => {
                (p.c.len() + p.c_fixed.len() + p.seg_bounds.len()) * 8 + 48
            }
            CalibValue::Piecewise(c) => c.len() * 16,
            CalibValue::ProductLut(l) => l.len() * 4,
        }
    }
}

/// Cache counters — the shared-LUT sharing story (§V of the paper) in
/// numbers: `hits / (hits + misses)` is the fraction of acquisitions served
/// without recalibration or rebuild.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Initialized entries resident.
    pub entries: usize,
    /// Acquisitions served from an existing entry.
    pub hits: u64,
    /// Acquisitions that computed the entry.
    pub misses: u64,
    /// Calibration closures actually entered (≥ misses: a panicking init
    /// leaves its slot empty, so the next acquisition attempts again).
    pub init_attempts: u64,
    /// Entries seeded from the on-disk artifact store.
    pub warm_loaded: u64,
    /// Payload bytes resident across all entries.
    pub resident_bytes: usize,
    /// Bytes that per-acquisition dedicated copies would have cost.
    pub dedicated_bytes: usize,
}

impl CacheStats {
    /// Calibration retries after a panicking init (the poison-safety
    /// contract in action: attempts beyond the one that completed).
    pub fn retries(&self) -> u64 {
        self.init_attempts.saturating_sub(self.misses)
    }

    /// Fractional storage saving versus per-acquisition dedicated copies
    /// (the §V shared-LUT benefit).
    pub fn saving(&self) -> f64 {
        if self.dedicated_bytes == 0 {
            0.0
        } else {
            1.0 - self.resident_bytes as f64 / self.dedicated_bytes as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "calib cache: {} entries ({} KiB resident), {} hits / {} misses, {} warm-loaded, sharing saves {:.1}%",
            self.entries,
            self.resident_bytes / 1024,
            self.hits,
            self.misses,
            self.warm_loaded,
            100.0 * self.saving()
        )
    }
}

type SlotMap = HashMap<CalibKey, Arc<OnceLock<CalibValue>>>;

/// The unified calibration cache. See the module docs for the poisoning
/// contract; see [`super::cache()`] for the process-wide instance.
#[derive(Default)]
pub struct CalibCache {
    slots: Mutex<SlotMap>,
    hits: AtomicU64,
    misses: AtomicU64,
    init_attempts: AtomicU64,
    warm_loaded: AtomicU64,
    /// Σ resident_bytes over acquisitions — what dedicated copies would
    /// have cost (the denominator of the sharing saving).
    dedicated_bytes: AtomicU64,
}

impl CalibCache {
    /// Fresh, empty cache (tests and tools; production code uses the
    /// process-wide [`super::cache()`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the slot map, recovering from poisoning: the map holds only
    /// bookkeeping state (no entry is ever half-written under it), so a
    /// poisoned lock is always safe to take over.
    fn slots(&self) -> std::sync::MutexGuard<'_, SlotMap> {
        self.slots.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Acquire the entry for `key`, computing it with `init` on first use.
    ///
    /// `init` runs *outside* the registry lock, on at most one thread per
    /// key at a time. If it panics, the panic propagates to the caller and
    /// the slot stays uninitialized — the next acquisition of the same key
    /// retries, and no other key is affected (the regression contract for
    /// the old poison-the-static failure mode).
    pub fn get_or_init<F: FnOnce() -> CalibValue>(&self, key: CalibKey, init: F) -> CalibValue {
        let slot = self.slots().entry(key).or_default().clone();
        if let Some(v) = slot.get() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.dedicated_bytes
                .fetch_add(v.resident_bytes() as u64, Ordering::Relaxed);
            return v.clone();
        }
        let mut computed = false;
        let v = slot.get_or_init(|| {
            // Counted before `init` runs: a panicking calibration still
            // registers as an attempt, so `attempts - misses` exposes the
            // retry count the poison-safety contract promises.
            self.init_attempts.fetch_add(1, Ordering::Relaxed);
            computed = true;
            init()
        });
        debug_assert_eq!(
            v.kind(),
            key.kind,
            "calib cache: value kind does not match key {key:?}"
        );
        if computed {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        self.dedicated_bytes
            .fetch_add(v.resident_bytes() as u64, Ordering::Relaxed);
        v.clone()
    }

    /// scaleTRIM constants for `(bits, h, m)` under a strategy, calibrating
    /// on first use. This is the acquisition path of
    /// [`ScaleTrim`](crate::multipliers::ScaleTrim) and
    /// [`LutRegistry`](crate::lut::LutRegistry).
    pub fn scaletrim_params(
        &self,
        bits: u32,
        h: u32,
        m: u32,
        strategy: CalibStrategy,
    ) -> Arc<ScaleTrimParams> {
        let spec = if strategy == CalibStrategy::Quantile {
            DesignSpec::ScaleTrimQ { h, m }
        } else {
            DesignSpec::ScaleTrim { h, m }
        };
        let key = CalibKey {
            spec,
            bits,
            strategy,
            kind: ArtifactKind::ScaleTrimParams,
        };
        match self.get_or_init(key, || {
            CalibValue::ScaleTrim(Arc::new(calibrator(strategy).calibrate(bits, h, m)))
        }) {
            CalibValue::ScaleTrim(p) => p,
            other => unreachable!("scaletrim key resolved to {:?}", other.kind()),
        }
    }

    /// Piecewise-linear coefficients for `(bits, h, segments)`, fitting on
    /// first use — the acquisition path of
    /// [`PiecewiseLinear`](crate::multipliers::PiecewiseLinear).
    pub fn piecewise_fit(&self, bits: u32, h: u32, segments: u32) -> Arc<Vec<(i64, i64)>> {
        let key = CalibKey {
            spec: DesignSpec::Piecewise { h, s: segments },
            bits,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::PiecewiseFit,
        };
        match self.get_or_init(key, || {
            CalibValue::Piecewise(Arc::new(fit_piecewise(bits, h, segments)))
        }) {
            CalibValue::Piecewise(c) => c,
            other => unreachable!("piecewise key resolved to {:?}", other.kind()),
        }
    }

    /// Shared signed product LUT for a multiplier instance, built in one
    /// batched pass on first use — the acquisition path of
    /// [`nn::cached_lut`](crate::nn::cached_lut) and the coordinator lanes.
    ///
    /// Invariant: at a given `(bits, strategy)`, a config *spec* must
    /// uniquely determine numerical behaviour — true for everything the
    /// registries and [`DesignSpec::build`] produce. Instances carrying
    /// externally supplied constants (`ScaleTrim::with_params`) are tagged
    /// [`CalibStrategy::External`], so they can never poison a
    /// self-calibrated config's slot; but two *different* external
    /// constant sets for the same `(h, M)` would still share the External
    /// slot — build those LUTs directly
    /// ([`nn::build_lut`](crate::nn::build_lut)).
    pub fn product_lut(&self, m: &dyn ApproxMultiplier) -> Arc<Vec<i32>> {
        let key = CalibKey {
            spec: m.spec(),
            bits: m.bits(),
            strategy: m.calib_strategy(),
            kind: ArtifactKind::ProductLut,
        };
        match self.get_or_init(key, || {
            CalibValue::ProductLut(Arc::new(crate::nn::build_lut(m)))
        }) {
            CalibValue::ProductLut(l) => l,
            other => unreachable!("product-lut key resolved to {:?}", other.kind()),
        }
    }

    /// Seed entries from the artifact store (warm start). Existing
    /// initialized slots are never overwritten — fresh calibration already
    /// in flight wins, keeping in-process state consistent. Entries whose
    /// value kind does not match the key are skipped. Returns the number
    /// of slots actually seeded.
    pub fn warm<I: IntoIterator<Item = (CalibKey, CalibValue)>>(&self, entries: I) -> usize {
        let mut seeded = 0usize;
        for (key, value) in entries {
            if value.kind() != key.kind {
                continue;
            }
            let slot = self.slots().entry(key).or_default().clone();
            if slot.set(value).is_ok() {
                seeded += 1;
                self.warm_loaded.fetch_add(1, Ordering::Relaxed);
            }
        }
        seeded
    }

    /// Snapshot the entry for a key without computing it.
    pub fn peek(&self, key: &CalibKey) -> Option<CalibValue> {
        let slots = self.slots();
        slots.get(key).and_then(|s| s.get().cloned())
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        let slots = self.slots();
        let mut entries = 0usize;
        let mut resident = 0usize;
        for slot in slots.values() {
            if let Some(v) = slot.get() {
                entries += 1;
                resident += v.resident_bytes();
            }
        }
        CacheStats {
            entries,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            init_attempts: self.init_attempts.load(Ordering::Relaxed),
            warm_loaded: self.warm_loaded.load(Ordering::Relaxed),
            resident_bytes: resident,
            dedicated_bytes: self.dedicated_bytes.load(Ordering::Relaxed) as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn key(h: u32, m: u32) -> CalibKey {
        CalibKey {
            spec: DesignSpec::ScaleTrim { h, m },
            bits: 8,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::ScaleTrimParams,
        }
    }

    #[test]
    fn same_key_shares_one_entry() {
        let c = CalibCache::new();
        let a = c.scaletrim_params(8, 3, 4, CalibStrategy::Exhaustive);
        let b = c.scaletrim_params(8, 3, 4, CalibStrategy::Exhaustive);
        assert!(Arc::ptr_eq(&a, &b), "same key must share one allocation");
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert!(s.saving() > 0.0, "second acquisition should count as saved");
    }

    #[test]
    fn strategy_is_part_of_the_key() {
        let c = CalibCache::new();
        let ex = c.scaletrim_params(8, 4, 8, CalibStrategy::Exhaustive);
        let sa = c.scaletrim_params(8, 4, 8, CalibStrategy::Sampled);
        assert!(!Arc::ptr_eq(&ex, &sa), "strategies must not collide");
        assert_eq!(c.stats().entries, 2);
    }

    /// The satellite regression: a panicking calibration must leave the
    /// cache fully usable — the same key retries, other keys never notice.
    #[test]
    fn panicking_init_does_not_poison_the_cache() {
        let c = CalibCache::new();
        let k = key(3, 4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            c.get_or_init(k, || panic!("injected calibration failure"));
        }));
        assert!(r.is_err(), "the injected panic must propagate");
        // Same key: retried, not dead.
        let v = c.get_or_init(k, || {
            CalibValue::ScaleTrim(Arc::new(crate::lut::calibrate(8, 3, 4)))
        });
        assert_eq!(v.kind(), ArtifactKind::ScaleTrimParams);
        // Other keys of the same width: untouched.
        let other = c.scaletrim_params(8, 4, 4, CalibStrategy::Exhaustive);
        assert_eq!(other.h, 4);
        // The failed attempt is visible as a retry in the counters: two
        // init closures entered for `k`, one miss completed.
        let s = c.stats();
        assert_eq!(s.retries(), 1, "attempts={} misses={}", s.init_attempts, s.misses);
    }

    #[test]
    fn warm_never_overwrites_and_reports_seeded_count() {
        let c = CalibCache::new();
        let fresh = c.scaletrim_params(8, 3, 4, CalibStrategy::Exhaustive);
        let mut doctored = (*fresh).clone();
        doctored.alpha += 1e-3;
        let seeded = c.warm(vec![
            (
                key(3, 4),
                CalibValue::ScaleTrim(Arc::new(doctored)),
            ),
            (
                key(3, 8),
                CalibValue::ScaleTrim(Arc::new(crate::lut::calibrate(8, 3, 8))),
            ),
        ]);
        assert_eq!(seeded, 1, "only the absent key is seeded");
        // The live entry won; the doctored artifact was dropped.
        let still = c.scaletrim_params(8, 3, 4, CalibStrategy::Exhaustive);
        assert_eq!(still.alpha.to_bits(), fresh.alpha.to_bits());
        // The seeded entry is served without a miss.
        let misses_before = c.stats().misses;
        let warmed = c.scaletrim_params(8, 3, 8, CalibStrategy::Exhaustive);
        assert_eq!(warmed.m, 8);
        assert_eq!(c.stats().misses, misses_before, "warm entry must be a hit");
    }

    #[test]
    fn warm_skips_kind_mismatches() {
        let c = CalibCache::new();
        let seeded = c.warm(vec![(
            key(3, 4),
            CalibValue::ProductLut(Arc::new(vec![0i32; 4])),
        )]);
        assert_eq!(seeded, 0);
        assert!(c.peek(&key(3, 4)).is_none());
    }

    #[test]
    fn piecewise_and_product_lut_paths_share() {
        let c = CalibCache::new();
        let a = c.piecewise_fit(8, 4, 4);
        let b = c.piecewise_fit(8, 4, 4);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 4);
        let m = crate::multipliers::ScaleTrim::new(8, 3, 4);
        let l1 = c.product_lut(&m);
        let l2 = c.product_lut(&m);
        assert!(Arc::ptr_eq(&l1, &l2));
        assert_eq!(l1.len(), 256 * 256);
    }
}
