//! The persistent calibration artifact store — versioned, checksummed
//! JSON on disk, so one process's design-time calibration is every later
//! process's file read.
//!
//! Layout: one bundle file (`calib-v1.json`) inside a `calib/` directory
//! that lives next to the model artifacts
//! [`crate::runtime::find_artifacts_dir`] already discovers
//! (`SCALETRIM_ARTIFACTS`, then `./artifacts` walking up). The document is
//!
//! ```json
//! {
//!   "format": "scaletrim-calib",
//!   "version": 1,
//!   "checksum": "fnv1a64:<16 hex digits>",
//!   "entries": [ { "spec": {...}, "bits": 8, "strategy": "exhaustive",
//!                  "kind": "scaletrim-params", "params": {...} }, ... ]
//! }
//! ```
//!
//! The checksum covers the canonical serialization of the `entries` array
//! (the writer is deterministic, so parse → re-serialize is the identity);
//! a load rejects wrong-format, wrong-version, wrong-checksum and
//! truncated documents with typed errors, and every loaded constant passes
//! the same [`ScaleTrimParams::try_validate`] gate as a fresh calibration.
//! Floating-point fields survive bit-for-bit: the JSON writer emits
//! shortest-round-trip `f64` text and the parser restores the identical
//! bits (pinned by `tests/prop_calib.rs`).
//!
//! Only design-time constants are persisted ([`ArtifactKind::ScaleTrimParams`],
//! [`ArtifactKind::PiecewiseFit`]). Product LUTs are derived data — a
//! single batched pass rebuilds them from the constants — so exporting one
//! is a typed error, not a 256 KiB JSON blob.

use super::cache::{ArtifactKind, CalibKey, CalibValue};
use super::strategy::CalibStrategy;
use crate::lut::ScaleTrimParams;
use crate::multipliers::DesignSpec;
use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::path::PathBuf;
use std::sync::Arc;

/// Bundle file name inside the store directory.
pub const STORE_FILE: &str = "calib-v1.json";

/// Format discriminant.
pub const STORE_FORMAT: &str = "scaletrim-calib";

/// Current artifact format version. Bump on any layout change: loads
/// reject other versions instead of guessing.
pub const STORE_VERSION: u64 = 1;

/// One persistable calibration artifact: key + value.
#[derive(Debug, Clone)]
pub struct StoreEntry {
    /// Cache key the artifact seeds.
    pub key: CalibKey,
    /// The constants.
    pub value: CalibValue,
}

/// A calibration artifact store rooted at one directory.
#[derive(Debug, Clone)]
pub struct CalibStore {
    dir: PathBuf,
}

impl CalibStore {
    /// Store rooted at an explicit directory (created on export).
    pub fn at(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    /// Store in the standard location: `<artifacts>/calib`, where
    /// `<artifacts>` is whatever [`crate::runtime::find_artifacts_dir`]
    /// resolves (the `SCALETRIM_ARTIFACTS` override, then `./artifacts`
    /// walking up). `None` when no artifacts directory exists at all.
    pub fn discover() -> Option<Self> {
        let dir = crate::runtime::find_artifacts_dir().ok()?;
        Some(Self::at(dir.join("calib")))
    }

    /// The bundle file path.
    pub fn path(&self) -> PathBuf {
        self.dir.join(STORE_FILE)
    }

    /// Serialize, checksum and write the entries. Returns the file path.
    ///
    /// The write is atomic (temp file + rename in the same directory), so
    /// a killed export can never leave a truncated bundle behind — readers
    /// see either the previous bundle or the complete new one.
    pub fn export(&self, entries: &[StoreEntry]) -> Result<PathBuf> {
        let doc = render_document(entries)?;
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating calib store dir {}", self.dir.display()))?;
        let path = self.path();
        let tmp = self.dir.join(format!("{STORE_FILE}.tmp.{}", std::process::id()));
        std::fs::write(&tmp, doc)
            .with_context(|| format!("writing calib artifacts to {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing calib artifacts at {}", path.display()))?;
        crate::obs::registry()
            .counter(crate::obs::names::metric::CALIB_STORE_EXPORTS_TOTAL, &[])
            .inc();
        Ok(path)
    }

    /// Load and fully verify the bundle (format, version, checksum, per-
    /// entry validation). Errors when the file is missing — use
    /// [`CalibStore::load_if_present`] for the warm-start path.
    pub fn load(&self) -> Result<Vec<StoreEntry>> {
        let path = self.path();
        let obs = crate::obs::registry();
        let loaded = std::fs::read_to_string(&path)
            .with_context(|| format!("reading calib artifacts from {}", path.display()))
            .and_then(|text| {
                parse_document(&text)
                    .with_context(|| format!("calib artifact file {}", path.display()))
            });
        match &loaded {
            Ok(_) => obs.counter(crate::obs::names::metric::CALIB_STORE_LOADS_TOTAL, &[]).inc(),
            Err(_) => {
                obs.counter(crate::obs::names::metric::CALIB_STORE_VERIFY_FAILURES_TOTAL, &[]).inc();
                crate::obs::record_error(crate::obs::names::error_source::CALIB_STORE_VERIFY);
            }
        }
        loaded
    }

    /// [`CalibStore::load`], returning `Ok(None)` when the bundle file does
    /// not exist (a store location with nothing in it is not an error).
    pub fn load_if_present(&self) -> Result<Option<Vec<StoreEntry>>> {
        if !self.path().is_file() {
            return Ok(None);
        }
        self.load().map(Some)
    }
}

/// FNV-1a 64-bit over a byte string — dependency-free integrity check.
/// (Integrity against corruption/truncation, not an adversarial MAC.)
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn checksum_tag(entries_json: &str) -> String {
    format!("fnv1a64:{:016x}", fnv1a64(entries_json.as_bytes()))
}

/// Serialize the full bundle document.
fn render_document(entries: &[StoreEntry]) -> Result<String> {
    let arr = Json::Arr(
        entries
            .iter()
            .map(entry_to_json)
            .collect::<Result<Vec<_>>>()?,
    );
    let entries_json = arr.to_string();
    let doc = Json::obj()
        .set("format", STORE_FORMAT)
        .set("version", STORE_VERSION)
        .set("checksum", checksum_tag(&entries_json))
        .set("entries", arr);
    Ok(doc.to_string())
}

/// Parse + verify the full bundle document.
fn parse_document(text: &str) -> Result<Vec<StoreEntry>> {
    let doc = Json::parse(text)
        .map_err(|e| anyhow!("unparseable (truncated or corrupt?): {e}"))?;
    let Json::Obj(fields) = &doc else {
        bail!("document root must be an object");
    };
    let get = |key: &str| fields.iter().find(|(k, _)| k == key).map(|(_, v)| v);
    match get("format") {
        Some(Json::Str(f)) if f == STORE_FORMAT => {}
        other => bail!("not a {STORE_FORMAT} document (format field: {other:?})"),
    }
    match get("version") {
        Some(Json::Num(v)) if *v == STORE_VERSION as f64 => {}
        Some(Json::Num(v)) => bail!(
            "unsupported artifact version {v} (this build reads version {STORE_VERSION}; \
             re-export with `scaletrim calib export`)"
        ),
        other => bail!("missing or malformed version field: {other:?}"),
    }
    let Some(Json::Str(declared)) = get("checksum") else {
        bail!("missing checksum field");
    };
    let Some(entries_val @ Json::Arr(items)) = get("entries") else {
        bail!("missing entries array");
    };
    // The writer is deterministic and parse∘write is the identity, so
    // re-serializing the parsed array reproduces the checksummed bytes.
    let actual = checksum_tag(&entries_val.to_string());
    if *declared != actual {
        bail!("checksum mismatch: file declares {declared}, content hashes to {actual}");
    }
    items
        .iter()
        .enumerate()
        .map(|(i, v)| entry_from_json(v).with_context(|| format!("entry {i}")))
        .collect()
}

fn entry_to_json(e: &StoreEntry) -> Result<Json> {
    let payload = match &e.value {
        CalibValue::ScaleTrim(p) => ("params", params_to_json(p)),
        CalibValue::Piecewise(c) => (
            "coef",
            Json::Arr(
                c.iter()
                    .map(|&(a, b)| Json::Arr(vec![Json::from(a), Json::from(b)]))
                    .collect(),
            ),
        ),
        CalibValue::ProductLut(_) => bail!(
            "product LUTs are derived artifacts — rebuild them from the constants \
             instead of persisting 256 KiB tables"
        ),
    };
    anyhow::ensure!(
        e.value.kind() == e.key.kind,
        "entry value kind {:?} does not match key kind {:?}",
        e.value.kind(),
        e.key.kind
    );
    Ok(Json::obj()
        .set("spec", e.key.spec.to_json())
        .set("bits", e.key.bits)
        .set("strategy", e.key.strategy.as_str())
        .set("kind", e.key.kind.as_str())
        .set(payload.0, payload.1))
}

fn entry_from_json(v: &Json) -> Result<StoreEntry> {
    let Json::Obj(fields) = v else {
        bail!("entry must be an object");
    };
    let get = |key: &str| {
        fields
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("entry missing field {key:?}"))
    };
    let spec = DesignSpec::from_json(get("spec")?)?;
    let bits = get_u32(get("bits")?, "bits")?;
    let strategy: CalibStrategy = match get("strategy")? {
        Json::Str(s) => s.parse().map_err(|e: String| anyhow!(e))?,
        other => bail!("strategy must be a string, got {}", other.to_string()),
    };
    let kind = match get("kind")? {
        Json::Str(s) => ArtifactKind::parse(s).map_err(|e| anyhow!(e))?,
        other => bail!("kind must be a string, got {}", other.to_string()),
    };
    let key = CalibKey {
        spec,
        bits,
        strategy,
        kind,
    };
    let value = match kind {
        ArtifactKind::ScaleTrimParams => {
            let p = params_from_json(get("params")?)?;
            p.try_validate().map_err(|e| anyhow!("invalid constants: {e}"))?;
            // The key and the payload must describe the same design point.
            let (kh, km) = match spec {
                DesignSpec::ScaleTrim { h, m } | DesignSpec::ScaleTrimQ { h, m } => (h, m),
                other => bail!("scaletrim-params entry keyed by non-scaleTRIM spec {other}"),
            };
            anyhow::ensure!(
                p.bits == bits && p.h == kh && p.m == km,
                "constants ({}, h={}, M={}) disagree with key ({bits}, h={kh}, M={km})",
                p.bits,
                p.h,
                p.m
            );
            // Segmentation shape must match the design family: a uniform
            // scaleTRIM key seeded with quantile boundaries would silently
            // switch the datapath's segment selection, and vice versa.
            let quantile_key = matches!(spec, DesignSpec::ScaleTrimQ { .. })
                && strategy == CalibStrategy::Quantile;
            let uniform_key = matches!(spec, DesignSpec::ScaleTrim { .. })
                && strategy != CalibStrategy::Quantile;
            anyhow::ensure!(
                quantile_key || uniform_key,
                "spec {spec} and strategy {strategy} disagree (scaleTRIM-Q ⇔ quantile)"
            );
            anyhow::ensure!(
                p.seg_bounds.is_empty() != quantile_key,
                "{spec}: {} segment boundaries do not fit a {} design",
                p.seg_bounds.len(),
                if quantile_key { "quantile" } else { "uniform" }
            );
            CalibValue::ScaleTrim(Arc::new(p))
        }
        ArtifactKind::PiecewiseFit => {
            let Json::Arr(items) = get("coef")? else {
                bail!("coef must be an array");
            };
            anyhow::ensure!(
                matches!(spec, DesignSpec::Piecewise { .. }),
                "piecewise-fit entry keyed by non-Piecewise spec {spec}"
            );
            if let DesignSpec::Piecewise { s, .. } = spec {
                anyhow::ensure!(
                    items.len() == s as usize,
                    "coef length {} disagrees with S={s}",
                    items.len()
                );
            }
            let coef = items
                .iter()
                .map(|it| match it {
                    Json::Arr(pair) if pair.len() == 2 => {
                        Ok((get_i64(&pair[0], "alpha")?, get_i64(&pair[1], "beta")?))
                    }
                    other => bail!("coef entries must be [alpha, beta] pairs, got {}", other.to_string()),
                })
                .collect::<Result<Vec<(i64, i64)>>>()?;
            CalibValue::Piecewise(Arc::new(coef))
        }
        ArtifactKind::ProductLut => bail!("product-lut entries are never persisted"),
    };
    Ok(StoreEntry { key, value })
}

fn params_to_json(p: &ScaleTrimParams) -> Json {
    Json::obj()
        .set("bits", p.bits)
        .set("h", p.h)
        .set("m", p.m)
        .set("alpha", p.alpha)
        .set("delta_ee", p.delta_ee as i64)
        .set("c", p.c.clone())
        .set("c_fixed", p.c_fixed.clone())
        .set(
            "seg_bounds",
            p.seg_bounds.iter().map(|&b| b as i64).collect::<Vec<i64>>(),
        )
}

fn params_from_json(v: &Json) -> Result<ScaleTrimParams> {
    let Json::Obj(fields) = v else {
        bail!("params must be an object");
    };
    let get = |key: &str| {
        fields
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("params missing field {key:?}"))
    };
    let num_arr = |key: &str| -> Result<Vec<f64>> {
        match get(key)? {
            Json::Arr(items) => items
                .iter()
                .map(|it| match it {
                    Json::Num(x) => Ok(*x),
                    other => bail!("{key} entries must be numbers, got {}", other.to_string()),
                })
                .collect(),
            other => bail!("{key} must be an array, got {}", other.to_string()),
        }
    };
    let alpha = match get("alpha")? {
        Json::Num(x) => *x,
        other => bail!("alpha must be a number, got {}", other.to_string()),
    };
    let delta_ee = get_i64(get("delta_ee")?, "delta_ee")?;
    anyhow::ensure!(
        (i32::MIN as i64..=i32::MAX as i64).contains(&delta_ee),
        "delta_ee {delta_ee} out of range"
    );
    let c = num_arr("c")?;
    let c_fixed = num_arr("c_fixed")?
        .into_iter()
        .map(|x| {
            anyhow::ensure!(x.fract() == 0.0, "c_fixed entry {x} is not an integer");
            Ok(x as i64)
        })
        .collect::<Result<Vec<i64>>>()?;
    let seg_bounds = num_arr("seg_bounds")?
        .into_iter()
        .map(|x| {
            anyhow::ensure!(
                x.fract() == 0.0 && x >= 0.0,
                "seg_bounds entry {x} is not a non-negative integer"
            );
            Ok(x as u64)
        })
        .collect::<Result<Vec<u64>>>()?;
    Ok(ScaleTrimParams {
        bits: get_u32(get("bits")?, "bits")?,
        h: get_u32(get("h")?, "h")?,
        m: get_u32(get("m")?, "m")?,
        alpha,
        delta_ee: delta_ee as i32,
        c,
        c_fixed,
        seg_bounds,
    })
}

fn get_u32(v: &Json, key: &str) -> Result<u32> {
    match v {
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u32::MAX as f64 => Ok(*x as u32),
        other => bail!("{key} must be a non-negative integer, got {}", other.to_string()),
    }
}

fn get_i64(v: &Json, key: &str) -> Result<i64> {
    match v {
        Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Ok(*x as i64),
        other => bail!("{key} must be an integer, got {}", other.to_string()),
    }
}

/// The standard export set at one width: every registered scaleTRIM
/// configuration calibrated exhaustively (the paper-fidelity constants),
/// the same family re-segmented by the quantile strategy (`scaleTRIM-Q`),
/// and the piecewise-linear ablation fit — i.e. everything a cold process
/// would otherwise have to scan `O(2^bits)` operands for.
pub fn default_export_entries(bits: u32) -> Result<Vec<StoreEntry>> {
    let mut entries = Vec::new();
    for spec in DesignSpec::enumerate(bits)? {
        let DesignSpec::ScaleTrim { h, m } = spec else {
            continue;
        };
        entries.push(StoreEntry {
            key: CalibKey {
                spec,
                bits,
                strategy: CalibStrategy::Exhaustive,
                kind: ArtifactKind::ScaleTrimParams,
            },
            value: CalibValue::ScaleTrim(Arc::new(crate::lut::calibrate(bits, h, m))),
        });
        if m >= 2 {
            entries.push(StoreEntry {
                key: CalibKey {
                    spec: DesignSpec::ScaleTrimQ { h, m },
                    bits,
                    strategy: CalibStrategy::Quantile,
                    kind: ArtifactKind::ScaleTrimParams,
                },
                value: CalibValue::ScaleTrim(Arc::new(
                    super::strategy::calibrator(CalibStrategy::Quantile).calibrate(bits, h, m),
                )),
            });
        }
    }
    // The Table-3 piecewise ablation point.
    let (ph, ps) = (4u32, 4u32);
    if ph < bits {
        entries.push(StoreEntry {
            key: CalibKey {
                spec: DesignSpec::Piecewise { h: ph, s: ps },
                bits,
                strategy: CalibStrategy::Exhaustive,
                kind: ArtifactKind::PiecewiseFit,
            },
            value: CalibValue::Piecewise(Arc::new(super::strategy::fit_piecewise(bits, ph, ps))),
        });
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> CalibStore {
        let dir = std::env::temp_dir().join(format!(
            "scaletrim-store-test-{tag}-{}",
            std::process::id()
        ));
        CalibStore::at(dir)
    }

    fn one_entry() -> StoreEntry {
        StoreEntry {
            key: CalibKey {
                spec: DesignSpec::ScaleTrim { h: 3, m: 4 },
                bits: 8,
                strategy: CalibStrategy::Exhaustive,
                kind: ArtifactKind::ScaleTrimParams,
            },
            value: CalibValue::ScaleTrim(Arc::new(crate::lut::calibrate(8, 3, 4))),
        }
    }

    #[test]
    fn export_load_round_trip() {
        let store = tmp_store("roundtrip");
        let entry = one_entry();
        store.export(std::slice::from_ref(&entry)).unwrap();
        let loaded = store.load().unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].key, entry.key);
        let (CalibValue::ScaleTrim(a), CalibValue::ScaleTrim(b)) =
            (&loaded[0].value, &entry.value)
        else {
            panic!("wrong value kinds");
        };
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "alpha must survive bit-for-bit");
        assert_eq!(a.c_fixed, b.c_fixed);
        assert_eq!(a.seg_bounds, b.seg_bounds);
    }

    #[test]
    fn load_if_present_on_empty_location() {
        let store = tmp_store("absent-location");
        assert!(store.load_if_present().unwrap().is_none());
        assert!(store.load().is_err(), "explicit load of a missing file errors");
    }

    #[test]
    fn product_luts_are_not_persistable() {
        let store = tmp_store("lut-reject");
        let entry = StoreEntry {
            key: CalibKey {
                spec: DesignSpec::ScaleTrim { h: 3, m: 4 },
                bits: 8,
                strategy: CalibStrategy::Exhaustive,
                kind: ArtifactKind::ProductLut,
            },
            value: CalibValue::ProductLut(Arc::new(vec![0i32; 16])),
        };
        let e = store.export(&[entry]).unwrap_err();
        assert!(e.to_string().contains("derived"), "{e}");
    }

    #[test]
    fn default_export_set_covers_the_family() {
        let entries = default_export_entries(8).unwrap();
        // 18 uniform scaleTRIM configs + 12 quantile (m>=2) + 1 piecewise.
        assert_eq!(entries.len(), 18 + 12 + 1, "expected the full 8-bit set");
        assert!(entries.iter().any(|e| e.key.kind == ArtifactKind::PiecewiseFit));
        assert!(entries
            .iter()
            .any(|e| matches!(e.key.spec, DesignSpec::ScaleTrimQ { .. })));
    }

    #[test]
    fn fnv_vector() {
        // Known FNV-1a 64 test vector.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
