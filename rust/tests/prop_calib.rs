//! The calibration plane's acceptance gate:
//!
//! - artifact round trips are **bit-for-bit**: export → load reproduces
//!   `ScaleTrimParams` (α down to the f64 bits, `c_fixed`, the quantile
//!   `seg_bounds`) and piecewise coefficients exactly, for every strategy;
//! - corrupted stores are typed rejections: wrong version, wrong
//!   checksum, truncated file, tampered entries;
//! - a warm-started cache serves constants identical to fresh calibration;
//! - a panicking calibration never poisons the cache (the old
//!   `Mutex<Option<HashMap>>` statics died here);
//! - Table 4 MRED anchors hold for every strategy that claims paper
//!   fidelity.

use scaletrim::calib::{
    calibrator, default_export_entries, ArtifactKind, CalibCache, CalibKey, CalibStore,
    CalibStrategy, CalibValue, StoreEntry,
};
use scaletrim::lut::calibrate;
use scaletrim::multipliers::{ApproxMultiplier, DesignSpec, PiecewiseLinear, ScaleTrim};
use scaletrim::util::prop::Runner;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Unique temp directory per call (tests run in parallel; one shared dir
/// would race on the bundle file).
fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "scaletrim-prop-calib-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn assert_params_bitwise_eq(
    a: &scaletrim::lut::ScaleTrimParams,
    b: &scaletrim::lut::ScaleTrimParams,
) -> Result<(), String> {
    if a.alpha.to_bits() != b.alpha.to_bits() {
        return Err(format!("alpha bits differ: {} vs {}", a.alpha, b.alpha));
    }
    if (a.bits, a.h, a.m, a.delta_ee) != (b.bits, b.h, b.m, b.delta_ee) {
        return Err("header fields differ".into());
    }
    if a.c.len() != b.c.len()
        || a.c.iter().zip(&b.c).any(|(x, y)| x.to_bits() != y.to_bits())
    {
        return Err(format!("c differs: {:?} vs {:?}", a.c, b.c));
    }
    if a.c_fixed != b.c_fixed {
        return Err(format!("c_fixed differs: {:?} vs {:?}", a.c_fixed, b.c_fixed));
    }
    if a.seg_bounds != b.seg_bounds {
        return Err(format!(
            "seg_bounds differ: {:?} vs {:?}",
            a.seg_bounds, b.seg_bounds
        ));
    }
    Ok(())
}

/// Property: export → load is the identity on calibration constants, for
/// random (strategy, h, M, bits) across the supported space.
#[test]
fn artifact_round_trip_is_bit_for_bit() {
    let dir = tmp_dir("roundtrip");
    let store = CalibStore::at(&dir);
    let mut r = Runner::new("calib-artifact-roundtrip", 30);
    r.run(|g| {
        let strategy = *g.choose(&CalibStrategy::ALL);
        let bits = *g.choose(&[6u32, 8]);
        let h = g.u32_in(2, 5);
        let m = *g.choose(&[0u32, 4, 8]);
        if strategy == CalibStrategy::Quantile && m < 2 {
            return Ok(()); // not a quantile design point
        }
        let params = calibrator(strategy).calibrate(bits, h, m);
        let spec = if strategy == CalibStrategy::Quantile {
            DesignSpec::ScaleTrimQ { h, m }
        } else {
            DesignSpec::ScaleTrim { h, m }
        };
        let entry = StoreEntry {
            key: CalibKey {
                spec,
                bits,
                strategy,
                kind: ArtifactKind::ScaleTrimParams,
            },
            value: CalibValue::ScaleTrim(Arc::new(params.clone())),
        };
        store
            .export(std::slice::from_ref(&entry))
            .map_err(|e| format!("export failed: {e}"))?;
        let loaded = store.load().map_err(|e| format!("load failed: {e}"))?;
        if loaded.len() != 1 || loaded[0].key != entry.key {
            return Err("key did not round-trip".into());
        }
        let CalibValue::ScaleTrim(back) = &loaded[0].value else {
            return Err("value kind did not round-trip".into());
        };
        assert_params_bitwise_eq(back, &params)
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn piecewise_fit_round_trips_exactly() {
    let dir = tmp_dir("piecewise");
    let store = CalibStore::at(&dir);
    for (h, s) in [(4u32, 4u32), (3, 8), (1, 2)] {
        let coef = scaletrim::calib::fit_piecewise(8, h, s);
        let entry = StoreEntry {
            key: CalibKey {
                spec: DesignSpec::Piecewise { h, s },
                bits: 8,
                strategy: CalibStrategy::Exhaustive,
                kind: ArtifactKind::PiecewiseFit,
            },
            value: CalibValue::Piecewise(Arc::new(coef.clone())),
        };
        store.export(&[entry]).unwrap();
        let loaded = store.load().unwrap();
        let CalibValue::Piecewise(back) = &loaded[0].value else {
            panic!("wrong kind");
        };
        assert_eq!(**back, coef, "h={h} S={s}: coefficients must be identical");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A warm-started cache must be indistinguishable from fresh calibration,
/// for the whole default export set (including `c_fixed` — the datapath
/// constants — and the quantile boundaries).
#[test]
fn warm_start_is_bit_for_bit_identical_to_fresh() {
    let dir = tmp_dir("warm");
    let store = CalibStore::at(&dir);
    let entries = default_export_entries(8).unwrap();
    store.export(&entries).unwrap();
    let loaded = store.load().unwrap();
    assert_eq!(loaded.len(), entries.len());

    let cache = CalibCache::new();
    let seeded = cache.warm(loaded.into_iter().map(|e| (e.key, e.value)));
    assert_eq!(seeded, entries.len(), "every exported entry must seed");

    for entry in &entries {
        match (&entry.key.spec, &entry.value) {
            (DesignSpec::ScaleTrim { h, m }, CalibValue::ScaleTrim(_)) => {
                let warmed = cache.scaletrim_params(8, *h, *m, CalibStrategy::Exhaustive);
                let fresh = calibrate(8, *h, *m);
                assert_params_bitwise_eq(&warmed, &fresh).unwrap_or_else(|e| {
                    panic!("scaleTRIM({h},{m}) warm != fresh: {e}")
                });
                // The warm constants drive the datapath identically.
                let a = ScaleTrim::with_params(8, (*warmed).clone());
                let b = ScaleTrim::with_params(8, fresh);
                for (x, y) in [(48u64, 81u64), (255, 255), (3, 200)] {
                    assert_eq!(a.mul(x, y), b.mul(x, y));
                }
            }
            (DesignSpec::ScaleTrimQ { h, m }, CalibValue::ScaleTrim(_)) => {
                let warmed = cache.scaletrim_params(8, *h, *m, CalibStrategy::Quantile);
                let fresh = calibrator(CalibStrategy::Quantile).calibrate(8, *h, *m);
                assert_params_bitwise_eq(&warmed, &fresh).unwrap_or_else(|e| {
                    panic!("scaleTRIM-Q({h},{m}) warm != fresh: {e}")
                });
            }
            (DesignSpec::Piecewise { h, s }, CalibValue::Piecewise(_)) => {
                let warmed = cache.piecewise_fit(8, *h, *s);
                let fresh = scaletrim::calib::fit_piecewise(8, *h, *s);
                assert_eq!(*warmed, fresh, "Piecewise(h={h},S={s}) warm != fresh");
            }
            other => panic!("unexpected export entry {other:?}"),
        }
    }
    // All of the above must have been served from the warm slots.
    assert_eq!(cache.stats().misses, 0, "warm start must not recalibrate");
    let _ = std::fs::remove_dir_all(&dir);
}

// --- corrupted-store rejections -----------------------------------------

fn valid_store_text(dir: &PathBuf) -> (CalibStore, String) {
    let store = CalibStore::at(dir);
    let entry = StoreEntry {
        key: CalibKey {
            spec: DesignSpec::ScaleTrim { h: 3, m: 4 },
            bits: 8,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::ScaleTrimParams,
        },
        value: CalibValue::ScaleTrim(Arc::new(calibrate(8, 3, 4))),
    };
    store.export(&[entry]).unwrap();
    let text = std::fs::read_to_string(store.path()).unwrap();
    (store, text)
}

#[test]
fn load_rejects_wrong_version() {
    let dir = tmp_dir("version");
    let (store, text) = valid_store_text(&dir);
    let tampered = text.replacen("\"version\":1", "\"version\":2", 1);
    assert_ne!(tampered, text, "the version field must exist to tamper");
    std::fs::write(store.path(), tampered).unwrap();
    let e = store.load().unwrap_err().to_string();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(
        e.contains("version") || chain.contains("version"),
        "error must name the version: {chain}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_rejects_wrong_checksum() {
    let dir = tmp_dir("checksum");
    let (store, text) = valid_store_text(&dir);
    // Flip the first checksum hex digit (0 <-> f keeps it hex).
    let idx = text.find("fnv1a64:").unwrap() + "fnv1a64:".len();
    let orig = text.as_bytes()[idx] as char;
    let flipped = if orig == 'f' { '0' } else { 'f' };
    let mut tampered = text.clone();
    tampered.replace_range(idx..idx + 1, &flipped.to_string());
    std::fs::write(store.path(), tampered).unwrap();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(chain.contains("checksum"), "{chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_rejects_tampered_entries() {
    let dir = tmp_dir("tamper");
    let (store, text) = valid_store_text(&dir);
    // Change a constant inside the checksummed region.
    let tampered = text.replacen("\"delta_ee\":-2", "\"delta_ee\":-1", 1);
    assert_ne!(tampered, text);
    std::fs::write(store.path(), tampered).unwrap();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(chain.contains("checksum"), "{chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_rejects_truncated_files() {
    let dir = tmp_dir("truncated");
    let (store, text) = valid_store_text(&dir);
    for frac in [2usize, 3, 10] {
        std::fs::write(store.path(), &text[..text.len() / frac]).unwrap();
        assert!(
            store.load().is_err(),
            "a 1/{frac}-length file must not load"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_rejects_invalid_constants_even_with_valid_checksum() {
    // A well-formed, correctly checksummed bundle whose constants violate
    // the datapath invariant (ΔEE < h − F) must still be rejected — the
    // store re-runs `try_validate`, it does not trust the file.
    let dir = tmp_dir("invalid-constants");
    let store = CalibStore::at(&dir);
    let mut params = calibrate(8, 3, 0);
    params.delta_ee = -14; // F − h + ΔEE = −1: the underflow case
    let entry = StoreEntry {
        key: CalibKey {
            spec: DesignSpec::ScaleTrim { h: 3, m: 0 },
            bits: 8,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::ScaleTrimParams,
        },
        value: CalibValue::ScaleTrim(Arc::new(params)),
    };
    // Export does not validate (it trusts in-process values — they passed
    // construction validation); craft the file directly.
    store.export(&[entry]).unwrap();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(chain.contains("linearization shift"), "{chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A well-formed, checksummed bundle must not be able to smuggle quantile
/// boundaries under a uniform scaleTRIM key (or mismatch spec/strategy):
/// that would silently switch the datapath's segment selection on warm
/// start.
#[test]
fn load_rejects_segmentation_shape_mismatches() {
    let dir = tmp_dir("shape-mismatch");
    let store = CalibStore::at(&dir);
    // Uniform key carrying quantile boundaries.
    let mut params = calibrate(8, 3, 4);
    params.seg_bounds = vec![3, 6, 9]; // passes try_validate on its own
    let entry = StoreEntry {
        key: CalibKey {
            spec: DesignSpec::ScaleTrim { h: 3, m: 4 },
            bits: 8,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::ScaleTrimParams,
        },
        value: CalibValue::ScaleTrim(Arc::new(params)),
    };
    store.export(&[entry]).unwrap();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(chain.contains("segment boundaries"), "{chain}");
    // Quantile spec keyed by a non-quantile strategy.
    let entry = StoreEntry {
        key: CalibKey {
            spec: DesignSpec::ScaleTrimQ { h: 3, m: 4 },
            bits: 8,
            strategy: CalibStrategy::Exhaustive,
            kind: ArtifactKind::ScaleTrimParams,
        },
        value: CalibValue::ScaleTrim(Arc::new(
            calibrator(CalibStrategy::Quantile).calibrate(8, 3, 4),
        )),
    };
    store.export(&[entry]).unwrap();
    let chain = format!("{:#}", store.load().unwrap_err());
    assert!(chain.contains("disagree"), "{chain}");
    let _ = std::fs::remove_dir_all(&dir);
}

// --- cache poisoning regression ------------------------------------------

/// The satellite fix, end to end: with the old per-module statics, one
/// panicking calibration poisoned the `Mutex` and every later acquisition
/// of that width died with it. The unified cache must retry the key and
/// leave every other key untouched — including across threads.
#[test]
fn poisoned_calibration_is_survivable() {
    let cache = Arc::new(CalibCache::new());
    let key = CalibKey {
        spec: DesignSpec::ScaleTrim { h: 5, m: 4 },
        bits: 8,
        strategy: CalibStrategy::Exhaustive,
        kind: ArtifactKind::ScaleTrimParams,
    };
    // Panic inside the init closure, on another thread (so the panic also
    // crosses a thread boundary, like a real racing calibration would).
    let c2 = cache.clone();
    let t = std::thread::spawn(move || {
        c2.get_or_init(key, || panic!("injected: invalid spec raced in"));
    });
    assert!(t.join().is_err(), "the injected panic must kill that thread");
    // Same key: retried and served.
    let p = cache.scaletrim_params(8, 5, 4, CalibStrategy::Exhaustive);
    assert_eq!((p.h, p.m), (5, 4));
    // Same width, different key: never affected.
    let q = cache.scaletrim_params(8, 5, 8, CalibStrategy::Exhaustive);
    assert_eq!(q.m, 8);
}

// --- paper anchors per strategy ------------------------------------------

/// Acceptance criterion: the Table 4 MRED anchors hold for every
/// calibration strategy that claims paper fidelity.
#[test]
fn table4_anchors_hold_for_every_paper_fidelity_strategy() {
    let anchors = [(3u32, 4u32, 3.73f64), (4, 8, 3.34), (5, 8, 2.12)];
    for strategy in CalibStrategy::ALL {
        let cal = calibrator(strategy);
        if !cal.paper_fidelity() {
            continue;
        }
        for (h, m, paper) in anchors {
            let mult = ScaleTrim::with_params(8, cal.calibrate(8, h, m));
            let mut sum = 0.0;
            for a in 1..256u64 {
                for b in 1..256u64 {
                    let exact = (a * b) as f64;
                    sum += ((mult.mul(a, b) as f64 - exact) / exact).abs();
                }
            }
            let mred = 100.0 * sum / (255.0 * 255.0);
            assert!(
                mred <= paper + 0.35,
                "{strategy} scaleTRIM({h},{m}): MRED {mred:.2} vs paper {paper}"
            );
        }
    }
}

/// The quantile family: a real design (parse → build → multiply), with
/// compensation that demonstrably works at equal LUT size.
#[test]
fn quantile_family_is_a_working_design() {
    let q: DesignSpec = "scaleTRIM-Q(4,8)".parse().unwrap();
    let mq = q.build(8).unwrap();
    assert_eq!(mq.spec(), q);
    assert_eq!(mq.calib_strategy(), CalibStrategy::Quantile);
    let m0 = ScaleTrim::new(8, 4, 0); // no compensation baseline
    let mut sum_q = 0.0;
    let mut sum_0 = 0.0;
    for a in 1..256u64 {
        for b in 1..256u64 {
            let exact = (a * b) as f64;
            sum_q += ((mq.mul(a, b) as f64 - exact) / exact).abs();
            sum_0 += ((m0.mul(a, b) as f64 - exact) / exact).abs();
        }
    }
    let (mred_q, mred_0) = (100.0 * sum_q / 65025.0, 100.0 * sum_0 / 65025.0);
    assert!(
        mred_q < mred_0,
        "quantile compensation must beat no compensation: {mred_q:.2} !< {mred_0:.2}"
    );
    // And it must be in the family of the uniform design at the same M.
    let mu = ScaleTrim::new(8, 4, 8);
    let mut sum_u = 0.0;
    for a in 1..256u64 {
        for b in 1..256u64 {
            let exact = (a * b) as f64;
            sum_u += ((mu.mul(a, b) as f64 - exact) / exact).abs();
        }
    }
    let mred_u = 100.0 * sum_u / 65025.0;
    assert!(
        mred_q <= mred_u + 0.5,
        "quantile segmentation far off uniform at equal M: {mred_q:.2} vs {mred_u:.2}"
    );
}

/// External constants (`with_params`) carry their own cache identity:
/// they can never poison — or be served — a self-calibrated config's
/// strategy-keyed slot, even when their spec matches.
#[test]
fn external_constants_never_share_cache_identity() {
    let external = ScaleTrim::with_params(8, calibrator(CalibStrategy::Sampled).calibrate(8, 3, 4));
    assert_eq!(external.calib_strategy(), CalibStrategy::External);
    assert_eq!(external.spec(), DesignSpec::ScaleTrim { h: 3, m: 4 });
    let cache = CalibCache::new();
    let ext_lut = cache.product_lut(&external);
    let own_lut = cache.product_lut(&ScaleTrim::new(8, 3, 4));
    assert!(
        !Arc::ptr_eq(&ext_lut, &own_lut),
        "external constants must occupy their own product-LUT slot"
    );
    // And External is an identity, not a requestable calibration.
    assert!(ScaleTrim::with_strategy(8, 3, 4, CalibStrategy::External).is_err());
}

/// Constructor alignment (satellite): `ScaleTrim` and `PiecewiseLinear`
/// direct construction go through the same typed validation as
/// `DesignSpec::build`.
#[test]
fn constructors_share_the_spec_error_path() {
    // scaleTRIM: h >= 2, via the spec's words.
    let direct = ScaleTrim::try_new(8, 1, 4).unwrap_err().to_string();
    let via_spec = DesignSpec::ScaleTrim { h: 1, m: 4 }
        .build(8)
        .unwrap_err()
        .to_string();
    assert_eq!(direct, via_spec);
    assert!(direct.contains(">= 2"), "{direct}");
    // Piecewise: h >= 1 is legal — the aligned rule, not scaleTRIM's.
    assert!(PiecewiseLinear::try_new(8, 1, 4).is_ok());
    // Width rules agree too.
    assert!(ScaleTrim::try_new(30, 4, 4).is_err(), "width cap is 24");
    assert!(ScaleTrim::try_new(8, 3, 3).is_err(), "M must be 0 or a power of two");
}
