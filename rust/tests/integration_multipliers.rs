//! Cross-module integration over the behavioural models + error sweeps:
//! the paper's accuracy orderings and family relationships must hold on
//! full-space measurements.

use ::scaletrim::error::{exhaustive_sweep, sweep, SweepSpec};
use ::scaletrim::multipliers::*;

fn mred(m: &dyn ApproxMultiplier) -> f64 {
    exhaustive_sweep(m).mred_pct
}

#[test]
fn scaletrim_family_orderings() {
    // Within a family: M=8 < M=4 < M=0 at fixed h; MRED drops with h up to
    // the compensation floor.
    for h in 3..=5u32 {
        let m0 = mred(&ScaleTrim::new(8, h, 0));
        let m4 = mred(&ScaleTrim::new(8, h, 4));
        let m8 = mred(&ScaleTrim::new(8, h, 8));
        assert!(m8 <= m4 && m4 < m0, "h={h}: {m8} {m4} {m0}");
    }
    assert!(mred(&ScaleTrim::new(8, 5, 8)) < mred(&ScaleTrim::new(8, 3, 8)));
}

#[test]
fn paper_cross_family_claims() {
    // Fig. 9 region claims on the (MRED) axis.
    let st34 = mred(&ScaleTrim::new(8, 3, 4));
    let st48 = mred(&ScaleTrim::new(8, 4, 8));
    let tosam15 = mred(&Tosam::new(8, 1, 5));
    let drum4 = mred(&Drum::new(8, 4));
    let mitchell = mred(&Mitchell::new(8));
    assert!(st48 < tosam15, "ST(4,8) {st48} should beat TOSAM(1,5) {tosam15}");
    assert!(st34 < drum4, "ST(3,4) {st34} should beat DRUM(4) {drum4}");
    assert!(st34 < mitchell + 0.1, "ST(3,4) {st34} ~ beats Mitchell {mitchell}");
}

#[test]
fn all_registry_configs_produce_bounded_outputs() {
    // Every design: outputs fit in 2n bits and zero behaves.
    for m in paper_configs_8bit() {
        assert_eq!(m.mul(0, 0), 0, "{}", m.name());
        for a in [1u64, 3, 127, 128, 255] {
            for b in [1u64, 2, 100, 255] {
                let p = m.mul(a, b);
                assert!(
                    p < 1 << 17,
                    "{}: {a}*{b} = {p} exceeds 2n+1 bits",
                    m.name()
                );
            }
        }
    }
}

#[test]
fn sixteen_bit_registry_sane() {
    let spec = SweepSpec::Sampled {
        pairs: 60_000,
        seed: 11,
    };
    for m in paper_configs_16bit() {
        let r = sweep(m.as_ref(), spec);
        assert!(
            r.mred_pct < 40.0,
            "{}: 16-bit MRED {:.2} out of family",
            m.name(),
            r.mred_pct
        );
    }
}

#[test]
fn scaletrim_16bit_beats_8bit_relative_error() {
    // More operand bits -> finer fractions -> lower MRED at equal (h, M).
    let spec = SweepSpec::Sampled {
        pairs: 300_000,
        seed: 3,
    };
    let m8 = sweep(&ScaleTrim::new(8, 5, 8), SweepSpec::Exhaustive).mred_pct;
    let m16 = sweep(&ScaleTrim::new(16, 5, 8), spec).mred_pct;
    assert!(
        (m16 - m8).abs() < 0.6,
        "MRED should be h-dominated, 8-bit {m8} vs 16-bit {m16}"
    );
}

#[test]
fn signed_wrapping_preserves_magnitude_accuracy() {
    let m = ScaleTrim::new(8, 4, 8);
    for (a, b) in [(57i64, -33i64), (-120, -5), (-1, 1), (90, 11)] {
        let signed = signed_mul(&m, a, b);
        let unsigned = m.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
        assert_eq!(signed.unsigned_abs(), unsigned.unsigned_abs());
        assert_eq!(signed < 0, (a < 0) ^ (b < 0) && signed != 0);
    }
}

#[test]
fn error_reports_consistent_across_paths() {
    // sweep() dispatch must agree with the direct functions.
    let m = ScaleTrim::new(8, 3, 4);
    let a = exhaustive_sweep(&m);
    let b = sweep(&m, SweepSpec::Exhaustive);
    assert_eq!(a.mred_pct, b.mred_pct);
    assert_eq!(a.pairs, b.pairs);
}
