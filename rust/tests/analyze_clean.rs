//! The repo-wide whole-program gate: the committed source tree must be
//! clean under `scaletrim analyze` — no lock-order findings, no
//! violated or unknown interval obligations in the kernel directories,
//! no declared/used drift. Same check CI runs, but as a plain
//! `cargo test` so a regression shows up in the tightest local loop
//! with every finding (and its counterexample witness) printed first.

use scaletrim::analysis::analyze_tree;
use std::path::Path;

#[test]
fn source_tree_is_analysis_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = analyze_tree(&root).expect("analyzing the source tree");
    for f in &report.findings {
        eprintln!("{}", f.render());
    }
    assert!(
        report.findings.is_empty(),
        "{} analysis finding(s) in the committed tree — run `scaletrim analyze` \
         (or see the lines above); suppress only with a reasoned \
         `analyze:allow` pragma",
        report.findings.len()
    );
}

#[test]
fn interval_analysis_actually_ran() {
    // Guard against the kernel-dir filter (or the item extractor)
    // silently matching nothing: the kernel fns carry hundreds of
    // shift/cast/index obligations across the four analysed widths.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = analyze_tree(&root).expect("analyzing the source tree");
    assert!(
        report.proved > 100,
        "only {} proved obligations — the interval analysis is not seeing \
         the kernel tree",
        report.proved
    );
    assert!(
        report.files > 40,
        "only {} files in the model — the walker is missing directories",
        report.files
    );
    assert!(
        report.lock_pairs > 0,
        "no lock-nesting pairs observed — the lock analysis is not seeing \
         the sync layer"
    );
}
