//! Integration tests for the application suite: exactness of the batched
//! MAC plumbing, the mul_batch-only execution contract, determinism, and
//! end-to-end quality/energy reporting.

use ::scaletrim::multipliers::{ApproxMultiplier, DesignSpec, Exact, ScaleTrim};
use ::scaletrim::workloads::{by_name, evaluate, quality, registry, sat_operand};

/// A multiplier that only exists on the batched plane: the scalar path
/// panics. Running the whole registry under it proves no workload inner
/// loop ever issues a per-pair `mul` — the ISSUE-2 acceptance criterion
/// for the batched kernel plane.
struct BatchOnly {
    bits: u32,
}

impl ApproxMultiplier for BatchOnly {
    // Identity of the behaviour it emulates (exact products); `name` is
    // overridden so failures still say which mock ran.
    fn spec(&self) -> DesignSpec {
        DesignSpec::Exact { bits: self.bits }
    }

    fn name(&self) -> String {
        "BatchOnly8".to_string()
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    fn mul(&self, _a: u64, _b: u64) -> u64 {
        panic!("scalar mul invoked: workload inner loops must go through mul_batch");
    }

    // Exact products, computed without touching the scalar path.
    fn mul_batch(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        assert_eq!(a.len(), b.len(), "mul_batch: operand slices differ");
        assert_eq!(a.len(), out.len(), "mul_batch: output slice differs");
        for ((&x, &y), o) in a.iter().zip(b.iter()).zip(out.iter_mut()) {
            *o = x * y;
        }
    }
}

/// Every registered workload, run under a scalar-panicking exact mock:
/// (a) never calls `mul` per pair, and (b) — because the mock's batch is
/// exact — reproduces the independent scalar reference bit-for-bit,
/// validating the MacPlane accumulation, sign and saturation plumbing.
#[test]
fn workloads_execute_batched_only_and_match_reference() {
    let mock = BatchOnly { bits: 8 };
    for w in registry() {
        let run = w.run(&mock);
        let reference = w.reference(8);
        assert_eq!(
            run.output,
            reference,
            "{}: batched-exact output diverges from the scalar reference",
            w.name()
        );
        assert!(run.macs > 0, "{}: no multiplications issued", w.name());
    }
}

/// Same exactness property under the real `Exact` design (whose override
/// is the monomorphized multiply loop).
#[test]
fn workloads_under_exact_match_reference_bit_for_bit() {
    let m = Exact::new(8);
    for w in registry() {
        assert_eq!(w.run(&m).output, w.reference(8), "{} diverged", w.name());
    }
}

/// Workloads are pure functions of their fixed seeds: identical outputs
/// and MAC counts across repeated runs.
#[test]
fn workloads_are_deterministic() {
    let m = ScaleTrim::new(8, 3, 4);
    for w in registry() {
        let a = w.run(&m);
        let b = w.run(&m);
        assert_eq!(a.output, b.output, "{} output drifted", w.name());
        assert_eq!(a.macs, b.macs, "{} MAC count drifted", w.name());
    }
}

/// End-to-end acceptance: `blur` under scaleTRIM(3,4) produces a usable
/// image (finite PSNR, positive SSIM) and a positive energy figure.
#[test]
fn blur_under_scaletrim_end_to_end() {
    let w = by_name("blur").expect("blur registered");
    let m = ScaleTrim::new(8, 3, 4);
    let r = evaluate(w.as_ref(), &m).expect("scaleTRIM(3,4) has a hardware model");
    assert!(
        r.quality.psnr_db.is_finite() && r.quality.psnr_db > 18.0,
        "PSNR {}",
        r.quality.psnr_db
    );
    assert!(r.quality.ssim > 0.5 && r.quality.ssim <= 1.0, "SSIM {}", r.quality.ssim);
    assert!(r.hw.area_um2 > 0.0 && r.hw.delay_ns > 0.0 && r.hw.pdp_fj > 0.0);
    assert!(r.energy_nj > 0.0 && r.macs > 0);
}

/// More accuracy buys more quality: scaleTRIM(6,8) must beat scaleTRIM(2,0)
/// on every workload (the knob the paper turns, observed at the
/// application level).
#[test]
fn quality_tracks_multiplier_accuracy() {
    let coarse = ScaleTrim::new(8, 2, 0);
    let fine = ScaleTrim::new(8, 6, 8);
    for w in registry() {
        let reference = w.reference(8);
        let q_coarse = quality::compare(&reference, &w.run(&coarse).output, 255.0);
        let q_fine = quality::compare(&reference, &w.run(&fine).output, 255.0);
        assert!(
            q_fine.psnr_db >= q_coarse.psnr_db,
            "{}: PSNR {:.2} (6,8) < {:.2} (2,0)",
            w.name(),
            q_fine.psnr_db,
            q_coarse.psnr_db
        );
    }
}

/// The width-saturation contract used by the MAC plane.
#[test]
fn sat_operand_clips_at_width() {
    assert_eq!(sat_operand(255, 8), 255);
    assert_eq!(sat_operand(256, 8), 255);
    assert_eq!(sat_operand(-300, 8), 255);
    assert_eq!(sat_operand(70_000, 16), 65_535);
    assert_eq!(sat_operand(0, 8), 0);
}

/// Workloads run unchanged under 16-bit configurations (wider datapath,
/// same 8-bit stimulus): exactness against the width-16 reference.
#[test]
fn workloads_run_at_16_bits() {
    let m = Exact::new(16);
    for w in registry() {
        assert_eq!(w.run(&m).output, w.reference(16), "{} diverged @16b", w.name());
    }
}
