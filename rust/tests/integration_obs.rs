//! Observability integration: coordinator traffic balances in the metrics
//! snapshot, both expositions round-trip against the snapshot they were
//! rendered from, spans/errors surface in the global registry, and
//! `Duration::MAX` saturates into the latency sketch instead of panicking
//! (regression for the old fixed-bucket `position().unwrap()` path).

use ::scaletrim::coordinator::{BatchPolicy, Coordinator, Metrics, MockBackend};
use ::scaletrim::multipliers::{ApproxMultiplier, Exact, ScaleTrim};
use ::scaletrim::obs;
use ::scaletrim::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

/// Round-robin `n` requests over two lanes of a mock backend, wait for
/// every response, quiesce. The returned coordinator's registry shard
/// holds the complete traffic accounting.
fn demo_coordinator(n: usize) -> Coordinator {
    let backend = Arc::new(MockBackend::new(4, 4));
    let exact = Exact::new(8);
    let st = ScaleTrim::new(8, 3, 4);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st];
    let coord = Coordinator::new(
        backend,
        &configs,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
    );
    let pending: Vec<_> = (0..n)
        .map(|i| {
            let lane = if i % 2 == 0 { "Exact8" } else { "scaleTRIM(3,4)" };
            coord.submit(lane, vec![i as u8 % 4, 0, 0, 0]).unwrap().1
        })
        .collect();
    for rx in pending {
        rx.recv().unwrap();
    }
    coord.shutdown();
    coord
}

#[test]
fn coordinator_shard_balances_and_passes_invariants() {
    let coord = demo_coordinator(32);
    let snap = coord.metrics().registry().snapshot();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum("coordinator_requests_total"), 32);
    assert_eq!(
        snap.counter_sum("coordinator_responses_ok_total")
            + snap.counter_sum("coordinator_responses_error_total"),
        32
    );
    assert!(snap.counter_sum("coordinator_batches_total") >= 8);
    // Per-lane latency sketches account for every response exactly once.
    let per_lane: u64 = snap
        .hists
        .iter()
        .filter(|(id, _)| id.name == "coordinator_latency_seconds" && !id.labels.is_empty())
        .map(|(_, h)| h.count())
        .sum();
    assert_eq!(per_lane, 32);
    // Queues drained back to zero after shutdown.
    for (id, v) in &snap.gauges {
        if id.name == "coordinator_queue_depth" {
            assert_eq!(*v, 0, "lane {} still has queued work", id.render());
        }
    }
}

#[test]
fn duration_max_latency_saturates_via_public_api() {
    let m = Metrics::new();
    m.record_latency(Duration::from_micros(100));
    m.record_latency(Duration::MAX);
    // The old fixed-bucket path panicked (`position().unwrap()`) or
    // silently truncated here; the sketch's catch-all last bin must
    // absorb it and keep every quantile finite and ordered.
    let p50 = m.latency_percentile_us(0.5);
    let p100 = m.latency_percentile_us(1.0);
    assert!(p100 > 1_000_000_000, "catch-all bin missing: p100={p100}µs");
    assert!(p50 <= p100);
    assert!(m.mean_latency_us().is_finite());
}

#[test]
fn empty_metrics_report_zero_not_panic() {
    let m = Metrics::new();
    assert_eq!(m.latency_percentile_us(0.99), 0);
    assert_eq!(m.mean_latency_us(), 0.0);
    assert_eq!(m.mean_occupancy(), 0.0);
}

#[test]
fn expositions_round_trip_against_snapshot() {
    let coord = demo_coordinator(16);
    let snap = coord.metrics().registry().snapshot();

    // Text: parse back and compare every histogram's _count series plus
    // the headline counter against the snapshot it came from.
    let text = obs::to_text(&snap);
    let parsed = obs::parse_text(&text).unwrap();
    assert_eq!(
        parsed["coordinator_requests_total"],
        snap.counter_sum("coordinator_requests_total") as f64
    );
    for (id, h) in &snap.hists {
        let base = id.render();
        let (bare, labels) = match base.find('{') {
            Some(i) => (&base[..i], &base[i..]),
            None => (base.as_str(), ""),
        };
        let key = format!("{bare}_count{labels}");
        assert_eq!(parsed[&key], h.count() as f64, "series {key}");
    }

    // JSON: schema-tagged, parseable by the in-repo parser, and the
    // counter values survive the round trip.
    let wire = obs::to_json(&snap).to_string();
    let back = Json::parse(&wire).unwrap();
    assert_eq!(
        back.get("schema").and_then(|s| s.as_str()),
        Some(obs::OBS_SCHEMA)
    );
    let counters = back.get("counters").and_then(|c| c.as_arr()).unwrap();
    let requests: f64 = counters
        .iter()
        .filter(|c| c.get("name").and_then(|n| n.as_str()) == Some("coordinator_requests_total"))
        .filter_map(|c| c.get("value").and_then(|v| v.as_f64()))
        .sum();
    assert_eq!(requests, 16.0);
}

#[test]
fn spans_and_errors_surface_in_global_snapshot() {
    {
        let span = obs::span("test.integration.obs");
        let _g = span.start();
        std::thread::sleep(Duration::from_millis(1));
    }
    obs::record_error("test.integration.obs.error");
    let snap = obs::snapshot_all();
    let span_count: u64 = snap
        .hists
        .iter()
        .filter(|(id, _)| {
            id.name == "scaletrim_span_seconds"
                && id.labels.iter().any(|(k, v)| *k == "span" && v == "test.integration.obs")
        })
        .map(|(_, h)| h.count())
        .sum();
    assert!(span_count >= 1, "span did not record into the registry");
    let errors: u64 = snap
        .counters
        .iter()
        .filter(|(id, _)| {
            id.name == "scaletrim_errors_total"
                && id.labels.iter().any(|(_, v)| v == "test.integration.obs.error")
        })
        .map(|(_, v)| v)
        .sum();
    assert!(errors >= 1, "error did not count into the registry");
    assert!(obs::recorder().recorded() >= 2, "flight recorder missed the events");
}
