//! Fixtures that pin the whole-program analyses' ability to *find*
//! things — each class of defect the `analyze` plane exists for is
//! reproduced in a small source fixture and must be caught, with the
//! diagnostic carrying enough context (call path, concrete operand
//! values) to act on. The committed tree being clean
//! (`analyze_clean.rs`) is only meaningful alongside these.

use scaletrim::analysis::{analyze_sources, TreeReport};

fn run(files: &[(&str, &str)]) -> TreeReport {
    run_with(files, &[])
}

fn run_with(files: &[(&str, &str)], extra: &[(&str, &str)]) -> TreeReport {
    analyze_sources(files, extra).expect("analysis must run")
}

// ---------------------------------------------------------------------
// Lock order
// ---------------------------------------------------------------------

#[test]
fn inverted_lock_order_is_a_cycle() {
    let src = "
pub struct Pair { a: std::sync::Mutex<u32>, b: std::sync::Mutex<u32> }
impl Pair {
    fn ab(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
    fn ba(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        drop(h);
        drop(g);
    }
}
";
    let report = run(&[("util/pair.rs", src)]);
    let nesting: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "lock-nesting")
        .collect();
    assert_eq!(nesting.len(), 2, "{:?}", report.findings);
    assert!(
        nesting[0]
            .message
            .contains("`Pair::ab` acquires `Pair.b` while holding `Pair.a` (held since line 5)"),
        "{}",
        nesting[0].message
    );
    let cycle: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "lock-cycle")
        .collect();
    assert_eq!(cycle.len(), 1);
    assert_eq!(cycle[0].file, "-");
    assert_eq!(cycle[0].line, 0);
    assert!(
        cycle[0]
            .message
            .contains("lock order cycle: Pair.a -> Pair.b -> Pair.a"),
        "{}",
        cycle[0].message
    );
}

// ---------------------------------------------------------------------
// Bitwidth intervals
// ---------------------------------------------------------------------

const BROKEN_SHIFT: &str = "
pub fn broken(a: [u64; 8], s: u32) -> u64 {
    let mut acc = 0u64;
    for i in 0..8 {
        acc ^= a[i] << s;
    }
    acc
}
";

#[test]
fn unguarded_shift_prints_an_operand_witness() {
    let extra = [("tests/t.rs", "fn t() { let _ = broken([0; 8], 1); }")];
    let report = run_with(&[("simd/mod.rs", BROKEN_SHIFT)], &extra);
    assert_eq!(report.violated, 4, "one violation per analysed width");
    let shifts: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "shift-range")
        .collect();
    assert_eq!(shifts.len(), 1, "width-deduplicated: {:?}", report.findings);
    let f = shifts[0];
    assert_eq!((f.file.as_str(), f.line), ("simd/mod.rs", 5));
    // The rendered diagnostic names the expression, the reachable bad
    // amount, the operand width, and a concrete witness assignment.
    let rendered = f.render();
    assert!(
        rendered.contains(
            "`a[i] << s`: amount `s` in [0,4294967295] can reach 4294967295 \
             but operand width is 64"
        ),
        "{rendered}"
    );
    assert!(
        rendered.ends_with("{'amount': 4294967295, 'expr': 'a[i] << s'}"),
        "witness must close the diagnostic: {rendered}"
    );
}

#[test]
fn guarded_shift_produces_no_finding() {
    let src = "pub fn shl(a: u64, s: u32) -> u64 { if s < 64 { a << s } else { 0 } }";
    let extra = [("tests/t.rs", "fn t() { let _ = shl(1, 2); }")];
    let report = run_with(&[("simd/mod.rs", src)], &extra);
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    assert_eq!(report.proved, 4);
}

#[test]
fn pragma_round_trip_suppresses_with_a_reason() {
    let suppressed = "
pub fn broken(a: [u64; 8], s: u32) -> u64 {
    let mut acc = 0u64;
    for i in 0..8 {
        // analyze:allow(shift-range): amount clamped by caller contract
        acc ^= a[i] << s;
    }
    acc
}
";
    let extra = [("tests/t.rs", "fn t() { let _ = broken([0; 8], 1); }")];
    let report = run_with(&[("simd/mod.rs", suppressed)], &extra);
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    assert_eq!(report.violated, 0, "suppressed obligations are allowed, not violated");
    // The same pragma without a reason must not suppress.
    let unreasoned = suppressed.replace(": amount clamped by caller contract", "");
    let report = run_with(&[("simd/mod.rs", unreasoned.as_str())], &extra);
    assert_eq!(report.violated, 4, "a bare pragma must not suppress");
}

// ---------------------------------------------------------------------
// Drift
// ---------------------------------------------------------------------

#[test]
fn orphaned_design_spec_variant_is_reported() {
    let files = [
        (
            "multipliers/spec.rs",
            "
pub enum DesignSpec { Exact, Trim }
fn enumerate() -> u32 { let _ = DesignSpec::Exact; 0 }
fn build() -> u32 { let _ = DesignSpec::Exact; 1 }
fn family() -> u32 { let _ = DesignSpec::Exact; 2 }
",
        ),
        ("hardware/designs.rs", "fn structural() -> u32 { let _ = DesignSpec::Exact; 3 }"),
    ];
    let report = run(&files);
    let drift: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "spec-drift")
        .collect();
    // `Trim` is missing from all four coverage fns; `Exact` is present
    // in each.
    assert_eq!(drift.len(), 4, "{:?}", report.findings);
    assert!(drift
        .iter()
        .all(|f| f.message.contains("`DesignSpec::Trim` has no arm in")));
    assert!(drift
        .iter()
        .any(|f| f.message.contains("`enumerate` (multipliers/spec.rs)")));
    // Findings anchor at the enum declaration so the fix site is the
    // variant list, not the match arms.
    assert!(drift.iter().all(|f| f.file == "multipliers/spec.rs"));
}

#[test]
fn unreferenced_pub_surface_and_obs_names_are_drift() {
    let files = [
        ("obs/names.rs", "pub const FOO_METRIC: &str = \"\";\n"),
        ("util/helpers.rs", "pub fn orphan(x: u32) -> u32 { x + 1 }\n"),
    ];
    let report = run(&files);
    let rules: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    assert!(rules.contains(&"dead-pub"), "{rules:?}");
    assert!(rules.contains(&"dead-name"), "{rules:?}");
    assert!(report
        .findings
        .iter()
        .any(|f| f.message.contains("`util/helpers.rs::orphan` is pub but mentioned nowhere else")));
    assert!(report
        .findings
        .iter()
        .any(|f| f.message.contains("obs name `FOO_METRIC` is never emitted")));
    // A use from the integration-test stream clears both.
    let extra = [("tests/t.rs", "fn t() { let _ = orphan(1); emit(FOO_METRIC); }")];
    let report = run_with(&files, &extra);
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}
