//! Runtime integration: the AOT HLO artifact, compiled and executed via
//! PJRT, must produce logits *bit-identical* to the pure-rust int8
//! interpreter for the same LUT — this is the contract that makes the
//! pure-rust sweeps (Figs. 15/16) valid stand-ins for the served model.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use scaletrim::multipliers::ScaleTrim;
use scaletrim::nn::{build_lut, exact_lut, Dataset, QuantizedCnn, QuantizedWeights};
use scaletrim::runtime::{find_artifacts_dir, ArtifactSet, Engine};

fn load(name: &str) -> Option<(ArtifactSet, Dataset, QuantizedCnn)> {
    let dir = find_artifacts_dir().ok()?;
    let set = ArtifactSet::resolve(&dir, name).ok()?;
    let data = Dataset::load(&set.dataset).ok()?;
    let cnn = QuantizedCnn::new(QuantizedWeights::load(&set.weights).ok()?);
    Some((set, data, cnn))
}

#[test]
fn pjrt_matches_pure_rust_bitwise() {
    let Some((set, data, cnn)) = load("lenet") else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let engine = Engine::cpu().expect("PJRT CPU client");
    let model = engine
        .load_model(set.hlo.to_str().unwrap(), 32, data.n_classes)
        .expect("compiling lenet artifact");

    for lut in [exact_lut(), build_lut(&ScaleTrim::new(8, 3, 4))] {
        // One batch of 32 images through both paths.
        let img_sz = data.c * data.h * data.w;
        let mut pixels = Vec::with_capacity(32 * img_sz);
        for i in 0..32 {
            pixels.extend(data.image(i).iter().map(|&p| p as i32));
        }
        let pjrt_logits = model
            .run(&pixels, &[32, data.c, data.h, data.w], &lut)
            .expect("pjrt run");
        for i in 0..32 {
            let rust_logits = cnn.forward(data.image(i), &lut);
            let pj = &pjrt_logits[i * data.n_classes..(i + 1) * data.n_classes];
            assert_eq!(
                pj, &rust_logits[..],
                "image {i}: PJRT and pure-rust logits diverge"
            );
        }
    }
}

#[test]
fn pjrt_accuracy_matches_meta() {
    let Some((set, data, _)) = load("lenet") else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let model = engine
        .load_model(set.hlo.to_str().unwrap(), 32, data.n_classes)
        .unwrap();
    let lut = exact_lut();
    let report =
        scaletrim::nn::evaluate_accuracy_pjrt(&model, &data, &lut, Some(320)).expect("eval");
    // aot.py recorded ~99% int8 accuracy for lenet; any healthy run is >0.9.
    assert!(
        report.top1 > 0.9,
        "lenet top1 {} unexpectedly low",
        report.top1
    );
}

#[test]
fn approximate_luts_change_but_do_not_destroy_accuracy() {
    let Some((_, data, cnn)) = load("lenet") else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let exact = scaletrim::nn::evaluate_accuracy(&cnn, &data, &exact_lut(), Some(400));
    let st = scaletrim::nn::evaluate_accuracy(
        &cnn,
        &data,
        &build_lut(&ScaleTrim::new(8, 4, 8)),
        Some(400),
    );
    assert!(
        st.top1 > exact.top1 - 0.05,
        "ST(4,8) {} vs exact {}",
        st.top1,
        exact.top1
    );
}

#[test]
fn all_four_artifacts_compile() {
    let Ok(dir) = find_artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    for name in ["lenet", "convnet_m", "convnet_l", "squeeze_s"] {
        let Ok(set) = ArtifactSet::resolve(&dir, name) else {
            eprintln!("skipping {name}: not present");
            continue;
        };
        let data = Dataset::load(&set.dataset).unwrap();
        let model = engine
            .load_model(set.hlo.to_str().unwrap(), 32, data.n_classes)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(model.n_classes, data.n_classes);
    }
}

#[test]
fn interpreter_and_pjrt_accuracy_sanity() {
    let Some((set, data, cnn)) = load("lenet") else { return };
    let lut = exact_lut();
    let r = scaletrim::nn::evaluate_accuracy(&cnn, &data, &lut, Some(500));
    assert!(r.top1 > 0.9, "pure-rust top1 {}", r.top1);
    let engine = Engine::cpu().unwrap();
    let model = engine
        .load_model(set.hlo.to_str().unwrap(), 32, data.n_classes)
        .unwrap();
    let rp = scaletrim::nn::evaluate_accuracy_pjrt(&model, &data, &lut, Some(160)).unwrap();
    assert!(
        (r.top1 - rp.top1).abs() < 0.05,
        "paths disagree: rust {} vs pjrt {}",
        r.top1,
        rp.top1
    );
}
