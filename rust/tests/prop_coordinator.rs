//! Property tests on coordinator invariants (routing, batching, state):
//! every request is answered exactly once, batches respect the policy cap,
//! responses carry the right ids, and the queue survives arbitrary
//! interleavings of producers, failures, and shutdown.

use ::scaletrim::coordinator::{BatchPolicy, BatchQueue, Coordinator, MockBackend, Request};
use ::scaletrim::multipliers::{ApproxMultiplier, Exact, ScaleTrim};
use ::scaletrim::util::prop::Runner;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn mk_request(id: u64, tx: mpsc::Sender<::scaletrim::coordinator::Prediction>) -> Request {
    Request {
        id,
        pixels: vec![(id % 251) as u8; 4],
        enqueued: Instant::now(),
        reply: tx,
    }
}

/// Random (n_requests, max_batch, max_wait) configurations: conservation —
/// exactly the pushed ids come back out, in FIFO order per lane, with no
/// batch exceeding the cap.
#[test]
fn prop_batch_queue_conservation() {
    let mut r = Runner::new("batch-queue-conservation", 60);
    r.run(|g| {
        let n = g.u64_in(1, 120);
        let max_batch = g.usize_in(1, 33);
        let wait_us = g.u64_in(50, 3000);
        let q = Arc::new(BatchQueue::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
        }));
        let (tx, _rx) = mpsc::channel();
        let producer = {
            let q = q.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                for id in 0..n {
                    assert!(q.push(mk_request(id, tx.clone())));
                }
                q.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = q.pop_batch() {
            if batch.len() > max_batch {
                return Err(format!("batch {} > cap {max_batch}", batch.len()));
            }
            seen.extend(batch.iter().map(|r| r.id));
        }
        producer.join().unwrap();
        let expected: Vec<u64> = (0..n).collect();
        if seen != expected {
            return Err(format!("ids out of order or lost: got {} ids", seen.len()));
        }
        Ok(())
    });
}

/// Coordinator end-to-end under random load patterns and injected backend
/// failures: every submit gets exactly one reply with a matching id.
#[test]
fn prop_coordinator_exactly_once() {
    let mut r = Runner::new("coordinator-exactly-once", 25);
    r.run(|g| {
        let batch = g.usize_in(1, 16);
        let fail_every = if g.bool() { g.usize_in(2, 9) } else { 0 };
        let n = g.usize_in(1, 200);
        let backend = Arc::new(MockBackend::new(batch, 4).with_failures(fail_every));
        let exact = Exact::new(8);
        let st = ScaleTrim::new(8, 3, 4);
        let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st];
        let coord = Coordinator::new(
            backend,
            &configs,
            BatchPolicy {
                max_batch: batch,
                max_wait: Duration::from_micros(300),
            },
        );
        let mut pending = Vec::new();
        for i in 0..n {
            let lane = if i % 2 == 0 { "Exact8" } else { "scaleTRIM(3,4)" };
            let (id, rx) = coord
                .submit(lane, vec![i as u8, 0, 0, 0])
                .map_err(|e| e.to_string())?;
            pending.push((id, rx));
        }
        for (id, rx) in pending {
            let p = rx
                .recv_timeout(Duration::from_secs(5))
                .map_err(|_| format!("request {id} never answered"))?;
            if p.id != id {
                return Err(format!("id mismatch: sent {id}, got {}", p.id));
            }
        }
        let m = coord.metrics();
        let (req, resp) = (m.requests(), m.responses());
        if req != n as u64 || resp != n as u64 {
            return Err(format!("conservation broken: {req} submitted, {resp} answered"));
        }
        if m.responses_ok() + m.responses_error() != resp {
            return Err("ok/error split does not cover every response".to_string());
        }
        Ok(())
    });
}

/// Occupancy accounting: sum of batch occupancies equals total responses.
#[test]
fn prop_occupancy_accounting() {
    let mut r = Runner::new("occupancy-accounting", 20);
    r.run(|g| {
        let batch = g.usize_in(2, 32);
        let n = g.usize_in(1, 150);
        let backend = Arc::new(MockBackend::new(batch, 2));
        let exact = Exact::new(8);
        let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
        let coord = Coordinator::new(
            backend,
            &configs,
            BatchPolicy {
                max_batch: batch,
                max_wait: Duration::from_micros(200),
            },
        );
        let rx: Vec<_> = (0..n)
            .map(|_| coord.submit("Exact8", vec![0; 4]).unwrap().1)
            .collect();
        for r in rx {
            r.recv().unwrap();
        }
        let m = coord.metrics();
        let occ_sum = m.occupancy_sum();
        let resp = m.responses();
        if occ_sum != resp {
            return Err(format!("occupancy sum {occ_sum} != responses {resp}"));
        }
        Ok(())
    });
}
