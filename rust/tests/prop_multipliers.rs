//! Property-based tests over the behavioural models (in-repo prop rig,
//! `util::prop`): structural invariants that must hold for *every* operand
//! pair and every configuration, with shrinking on failure.

use ::scaletrim::multipliers::*;
use ::scaletrim::util::prop::Runner;

/// Every design in the registry: zero annihilates, outputs bounded, and
/// relative error within the family's published envelope.
#[test]
fn prop_zoo_global_invariants() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("zoo-global-invariants", 2000);
    r.run(|g| {
        let m = g.choose(&zoo);
        let a = g.u64_in(0, 255);
        let b = g.u64_in(0, 255);
        let p = m.mul(a, b);
        if a == 0 || b == 0 {
            // Most designs zero-detect; those that don't (array-based) still
            // produce 0 because all partial products vanish.
            if p != 0 {
                return Err(format!("{}: {a}*{b} = {p}, expected 0", m.name()));
            }
            return Ok(());
        }
        if p >= 1 << 17 {
            return Err(format!("{}: {a}*{b} = {p} exceeds 17 bits", m.name()));
        }
        let exact = (a * b) as f64;
        let ared = (p as f64 - exact).abs() / exact;
        // Widest family envelope in Table 4 is MBM-5 at ~27% MRED; allow
        // generous per-pair headroom (max error, not mean).
        if ared > 1.0 {
            return Err(format!(
                "{}: {a}*{b} = {p} (exact {exact}), ARED {ared:.3} > 100%",
                m.name()
            ));
        }
        Ok(())
    });
}

/// scaleTRIM-specific: commutativity, monotone non-degradation with M, and
/// the Table-5 max-error envelope.
#[test]
fn prop_scaletrim_invariants() {
    let st34 = ScaleTrim::new(8, 3, 4);
    let st30 = ScaleTrim::new(8, 3, 0);
    let mut r = Runner::new("scaletrim-invariants", 3000);
    r.run(|g| {
        let a = g.u64_in(1, 255);
        let b = g.u64_in(1, 255);
        if st34.mul(a, b) != st34.mul(b, a) {
            return Err(format!("not commutative at {a},{b}"));
        }
        let exact = (a * b) as f64;
        let ared = (st34.mul(a, b) as f64 - exact).abs() / exact;
        // Table 5: scaleTRIM(3,4) max ED 6177 over the whole space; the
        // relative envelope stays under ~25%.
        if ared > 0.25 {
            return Err(format!("ARED {ared:.3} at {a}*{b} beyond envelope"));
        }
        let _ = st30.mul(a, b); // must not panic anywhere in the domain
        Ok(())
    });
}

/// Truncation helper: reconstructing from the truncated fraction never
/// overshoots the operand and loses at most the dropped-bit mass.
#[test]
fn prop_truncation_bounds() {
    let mut r = Runner::new("truncation-bounds", 4000);
    r.run(|g| {
        let v = g.u64_in(1, 65_535);
        let h = g.u32_in(1, 8);
        let n = leading_one(v);
        let xh = truncate_fraction(v, n, h);
        if xh >= 1 << h {
            return Err(format!("xh {xh} exceeds h={h} bits for v={v}"));
        }
        // Reconstruct: 2^n (1 + xh/2^h) <= v  and the gap is < 2^n · 2^-h'
        // where h' = min(h, n).
        let recon = (1u64 << n) + ((xh << n) >> h);
        if recon > v {
            return Err(format!("reconstruction {recon} > v {v} (h={h})"));
        }
        let gap = v - recon;
        let bound = (1u64 << n) >> h.min(n);
        if n > h && gap >= bound.max(1) {
            return Err(format!("gap {gap} >= bound {bound} for v={v} h={h}"));
        }
        Ok(())
    });
}

/// The batched kernel plane can never drift from the scalar reference:
/// for every design in the registry, `mul_batch` over a random slice
/// (random length, including empty) equals per-element `mul`. This is the
/// contract that lets sweeps, LUT builders and `CompiledMul` route through
/// the monomorphized overrides blindly.
#[test]
fn prop_mul_batch_matches_scalar() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("mul-batch-matches-scalar", 600);
    r.run(|g| {
        let m = g.choose(&zoo);
        let len = g.usize_in(0, 300);
        let a: Vec<u64> = (0..len).map(|_| g.u64_in(0, 255)).collect();
        let b: Vec<u64> = (0..len).map(|_| g.u64_in(0, 255)).collect();
        let mut out = vec![0u64; len];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..len {
            let scalar = m.mul(a[i], b[i]);
            if out[i] != scalar {
                return Err(format!(
                    "{}: batch[{i}] = {} but mul({}, {}) = {scalar}",
                    m.name(),
                    out[i],
                    a[i],
                    b[i]
                ));
            }
        }
        Ok(())
    });
}

/// Same drift guard for the compiled table kernel, which additionally
/// narrows storage to u32: compiled scalar and batch must equal the
/// source design everywhere it was tabulated.
#[test]
fn prop_compiled_matches_source() {
    let zoo = paper_configs_8bit();
    let compiled: Vec<CompiledMul> = zoo.iter().map(|m| CompiledMul::compile(m.as_ref())).collect();
    let mut r = Runner::new("compiled-matches-source", 600);
    r.run(|g| {
        let i = g.usize_in(0, zoo.len() - 1);
        let (src, c) = (&zoo[i], &compiled[i]);
        let a = g.u64_in(0, 255);
        let b = g.u64_in(0, 255);
        if c.mul(a, b) != src.mul(a, b) {
            return Err(format!("{}: table diverges at {a}*{b}", src.name()));
        }
        Ok(())
    });
}

/// Signed wrapping: sign algebra and magnitude preservation for every
/// design in the registry.
#[test]
fn prop_signed_mul() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("signed-mul", 2000);
    r.run(|g| {
        let m = g.choose(&zoo);
        let a = g.u64_in(0, 255) as i64 * if g.bool() { -1 } else { 1 };
        let b = g.u64_in(0, 255) as i64 * if g.bool() { -1 } else { 1 };
        let s = signed_mul(m.as_ref(), a, b);
        let mag = m.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
        if s.unsigned_abs() != mag.unsigned_abs() {
            return Err(format!("{}: |{a}*{b}| mismatch", m.name()));
        }
        if s != 0 && (s < 0) != ((a < 0) ^ (b < 0)) {
            return Err(format!("{}: sign of {a}*{b} wrong", m.name()));
        }
        Ok(())
    });
}

/// DRUM's unbiasing: over random operand windows the signed error is
/// centred (sampled mean within a small band).
#[test]
fn prop_drum_unbiased_sampled() {
    use ::scaletrim::util::rng::Xoshiro256;
    let d = Drum::new(8, 4);
    let mut rng = Xoshiro256::seed_from_u64(99);
    let mut sum = 0f64;
    let n = 200_000;
    for _ in 0..n {
        let a = rng.gen_operand(8);
        let b = rng.gen_operand(8);
        sum += d.mul(a, b) as f64 - (a * b) as f64;
    }
    let mean = sum / n as f64;
    assert!(mean.abs() < 160.0, "sampled mean error {mean} not centred");
}
