//! Property-based tests over the behavioural models (in-repo prop rig,
//! `util::prop`): structural invariants that must hold for *every* operand
//! pair and every configuration, with shrinking on failure.

use ::scaletrim::multipliers::*;
use ::scaletrim::util::prop::Runner;

/// Every design in the registry: zero annihilates, outputs bounded, and
/// relative error within the family's published envelope.
#[test]
fn prop_zoo_global_invariants() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("zoo-global-invariants", 2000);
    r.run(|g| {
        let m = g.choose(&zoo);
        let a = g.u64_in(0, 255);
        let b = g.u64_in(0, 255);
        let p = m.mul(a, b);
        if a == 0 || b == 0 {
            // Most designs zero-detect; those that don't (array-based) still
            // produce 0 because all partial products vanish.
            if p != 0 {
                return Err(format!("{}: {a}*{b} = {p}, expected 0", m.name()));
            }
            return Ok(());
        }
        if p >= 1 << 17 {
            return Err(format!("{}: {a}*{b} = {p} exceeds 17 bits", m.name()));
        }
        let exact = (a * b) as f64;
        let ared = (p as f64 - exact).abs() / exact;
        // Widest family envelope in Table 4 is MBM-5 at ~27% MRED; allow
        // generous per-pair headroom (max error, not mean).
        if ared > 1.0 {
            return Err(format!(
                "{}: {a}*{b} = {p} (exact {exact}), ARED {ared:.3} > 100%",
                m.name()
            ));
        }
        Ok(())
    });
}

/// scaleTRIM-specific: commutativity, monotone non-degradation with M, and
/// the Table-5 max-error envelope.
#[test]
fn prop_scaletrim_invariants() {
    let st34 = ScaleTrim::new(8, 3, 4);
    let st30 = ScaleTrim::new(8, 3, 0);
    let mut r = Runner::new("scaletrim-invariants", 3000);
    r.run(|g| {
        let a = g.u64_in(1, 255);
        let b = g.u64_in(1, 255);
        if st34.mul(a, b) != st34.mul(b, a) {
            return Err(format!("not commutative at {a},{b}"));
        }
        let exact = (a * b) as f64;
        let ared = (st34.mul(a, b) as f64 - exact).abs() / exact;
        // Table 5: scaleTRIM(3,4) max ED 6177 over the whole space; the
        // relative envelope stays under ~25%.
        if ared > 0.25 {
            return Err(format!("ARED {ared:.3} at {a}*{b} beyond envelope"));
        }
        let _ = st30.mul(a, b); // must not panic anywhere in the domain
        Ok(())
    });
}

/// Truncation helper: reconstructing from the truncated fraction never
/// overshoots the operand and loses at most the dropped-bit mass.
#[test]
fn prop_truncation_bounds() {
    let mut r = Runner::new("truncation-bounds", 4000);
    r.run(|g| {
        let v = g.u64_in(1, 65_535);
        let h = g.u32_in(1, 8);
        let n = leading_one(v);
        let xh = truncate_fraction(v, n, h);
        if xh >= 1 << h {
            return Err(format!("xh {xh} exceeds h={h} bits for v={v}"));
        }
        // Reconstruct: 2^n (1 + xh/2^h) <= v  and the gap is < 2^n · 2^-h'
        // where h' = min(h, n).
        let recon = (1u64 << n) + ((xh << n) >> h);
        if recon > v {
            return Err(format!("reconstruction {recon} > v {v} (h={h})"));
        }
        let gap = v - recon;
        let bound = (1u64 << n) >> h.min(n);
        if n > h && gap >= bound.max(1) {
            return Err(format!("gap {gap} >= bound {bound} for v={v} h={h}"));
        }
        Ok(())
    });
}

/// The batched kernel plane can never drift from the scalar reference:
/// for every design in the registry, `mul_batch` over a random slice
/// (random length, including empty) equals per-element `mul`. This is the
/// contract that lets sweeps, LUT builders and `CompiledMul` route through
/// the monomorphized overrides blindly.
#[test]
fn prop_mul_batch_matches_scalar() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("mul-batch-matches-scalar", 600);
    r.run(|g| {
        let m = g.choose(&zoo);
        let len = g.usize_in(0, 300);
        let a: Vec<u64> = (0..len).map(|_| g.u64_in(0, 255)).collect();
        let b: Vec<u64> = (0..len).map(|_| g.u64_in(0, 255)).collect();
        let mut out = vec![0u64; len];
        m.mul_batch(&a, &b, &mut out);
        for i in 0..len {
            let scalar = m.mul(a[i], b[i]);
            if out[i] != scalar {
                return Err(format!(
                    "{}: batch[{i}] = {} but mul({}, {}) = {scalar}",
                    m.name(),
                    out[i],
                    a[i],
                    b[i]
                ));
            }
        }
        Ok(())
    });
}

/// Build the full enumerable zoo at a width via the typed identity plane
/// — every `DesignSpec::enumerate(bits)` spec, not just the paper-table
/// subset, so the SIMD==scalar contract is checked for designs that only
/// have the trait-default (`mul_batch_simd` → `mul_batch`) too.
fn enumerated_zoo(bits: u32) -> Vec<Box<dyn ApproxMultiplier>> {
    DesignSpec::enumerate(bits)
        .expect("enumerable width")
        .iter()
        .map(|s| s.build(bits).expect("enumerated specs build"))
        .collect()
}

/// Deterministic guarantee behind the random properties below: every
/// enumerable spec at `bits` sees one odd-length batch (crossing the lane
/// width, tail of 3) with a zero-dense operand stream, and `mul_batch_simd`
/// must equal per-element `mul` bit for bit.
fn assert_simd_matches_scalar_all_specs(bits: u32) {
    use ::scaletrim::util::rng::Xoshiro256;
    let len = 4 * scaletrim::simd::LANES + 3;
    for m in enumerated_zoo(bits) {
        let mut rng = Xoshiro256::seed_from_u64(0x51D0 + u64::from(bits));
        // gen_operand never returns 0; the coin flip restores a ~50%
        // zero-dense stream so the pre-masking path is always exercised.
        let a: Vec<u64> = (0..len).map(|_| rng.gen_operand(bits) * rng.gen_range(2)).collect();
        let b: Vec<u64> = (0..len).map(|_| rng.gen_operand(bits) * rng.gen_range(2)).collect();
        let mut out = vec![0u64; len];
        m.mul_batch_simd(&a, &b, &mut out);
        for i in 0..len {
            assert_eq!(
                out[i],
                m.mul(a[i], b[i]),
                "{}: simd[{i}] diverges at {}*{}",
                m.name(),
                a[i],
                b[i]
            );
        }
    }
}

/// The SIMD kernel plane can never drift from the scalar reference:
/// for every enumerable 8-bit spec, `mul_batch_simd` over random batches
/// equals per-element `mul` bit for bit. Lengths are drawn to cross the
/// lane width at every residue (tail handling off the lane width is the
/// classic SIMD bug), and operands are zero-dense with probability ~1/3
/// so the branchless zero pre-masking is exercised, not just the happy
/// path.
#[test]
fn prop_mul_batch_simd_matches_scalar_8bit() {
    assert_simd_matches_scalar_all_specs(8);
    let zoo = enumerated_zoo(8);
    let mut r = Runner::new("mul-batch-simd-matches-scalar-8", 600);
    r.run(|g| {
        let m = g.choose(&zoo);
        // 0..=4*LANES+3 covers empty, sub-lane, exact-lane and tailed
        // lengths for LANES = 8.
        let len = g.usize_in(0, 4 * scaletrim::simd::LANES + 3);
        let zero_dense = g.bool();
        let a: Vec<u64> = (0..len)
            .map(|_| {
                let v = g.u64_in(0, 255);
                if zero_dense && g.u32_in(0, 2) == 0 {
                    0
                } else {
                    v
                }
            })
            .collect();
        let b: Vec<u64> = (0..len)
            .map(|_| {
                let v = g.u64_in(0, 255);
                if zero_dense && g.u32_in(0, 2) == 0 {
                    0
                } else {
                    v
                }
            })
            .collect();
        let mut out = vec![0u64; len];
        m.mul_batch_simd(&a, &b, &mut out);
        for i in 0..len {
            let scalar = m.mul(a[i], b[i]);
            if out[i] != scalar {
                return Err(format!(
                    "{}: simd[{i}] (len {len}) = {} but mul({}, {}) = {scalar}",
                    m.name(),
                    out[i],
                    a[i],
                    b[i]
                ));
            }
        }
        Ok(())
    });
}

/// Same contract at 16 bits — the width where scaleTRIM(5,8) and
/// TOSAM(3,7) actually run and where the truncation paths take the
/// `n >= h` branch far more often.
#[test]
fn prop_mul_batch_simd_matches_scalar_16bit() {
    assert_simd_matches_scalar_all_specs(16);
    let zoo = enumerated_zoo(16);
    let mut r = Runner::new("mul-batch-simd-matches-scalar-16", 400);
    r.run(|g| {
        let m = g.choose(&zoo);
        let len = g.usize_in(0, 4 * scaletrim::simd::LANES + 3);
        let zero_dense = g.bool();
        let a: Vec<u64> = (0..len)
            .map(|_| {
                let v = g.u64_in(0, 65_535);
                if zero_dense && g.u32_in(0, 2) == 0 {
                    0
                } else {
                    v
                }
            })
            .collect();
        let b: Vec<u64> = (0..len)
            .map(|_| {
                let v = g.u64_in(0, 65_535);
                if zero_dense && g.u32_in(0, 2) == 0 {
                    0
                } else {
                    v
                }
            })
            .collect();
        let mut out = vec![0u64; len];
        m.mul_batch_simd(&a, &b, &mut out);
        for i in 0..len {
            let scalar = m.mul(a[i], b[i]);
            if out[i] != scalar {
                return Err(format!(
                    "{}: simd[{i}] (len {len}) = {} but mul({}, {}) = {scalar}",
                    m.name(),
                    out[i],
                    a[i],
                    b[i]
                ));
            }
        }
        Ok(())
    });
}

/// Exhaustive lane coverage for the hand-written kernels at the widths
/// the lane bodies specialise: every full-lane block of the sequential
/// operand space for the designs with real SIMD overrides. Complements
/// the random property above with deterministic coverage of the
/// scaleTRIM segment boundaries and the Mitchell `X + Y ≥ 1` carry case.
#[test]
fn simd_kernels_exhaustive_lane_blocks() {
    let kernels: Vec<Box<dyn ApproxMultiplier>> = vec![
        Box::new(Exact::new(8)),
        Box::new(Mitchell::new(8)),
        Box::new(ScaleTrim::new(8, 3, 4)),
        Box::new(ScaleTrim::new(8, 5, 8)),
        Box::new(Tosam::new(8, 1, 5)),
    ];
    let a: Vec<u64> = (0..256u64).flat_map(|x| std::iter::repeat_n(x, 256)).collect();
    let b: Vec<u64> = (0..256).flat_map(|_| 0..256u64).collect();
    let mut out = vec![0u64; a.len()];
    for m in &kernels {
        m.mul_batch_simd(&a, &b, &mut out);
        for ((&x, &y), &p) in a.iter().zip(b.iter()).zip(out.iter()) {
            assert_eq!(p, m.mul(x, y), "{}: {x}*{y}", m.name());
        }
    }
}

/// Same drift guard for the compiled table kernel, which additionally
/// narrows storage to u32: compiled scalar and batch must equal the
/// source design everywhere it was tabulated.
#[test]
fn prop_compiled_matches_source() {
    let zoo = paper_configs_8bit();
    let compiled: Vec<CompiledMul> = zoo.iter().map(|m| CompiledMul::compile(m.as_ref())).collect();
    let mut r = Runner::new("compiled-matches-source", 600);
    r.run(|g| {
        let i = g.usize_in(0, zoo.len() - 1);
        let (src, c) = (&zoo[i], &compiled[i]);
        let a = g.u64_in(0, 255);
        let b = g.u64_in(0, 255);
        if c.mul(a, b) != src.mul(a, b) {
            return Err(format!("{}: table diverges at {a}*{b}", src.name()));
        }
        Ok(())
    });
}

/// Signed wrapping: sign algebra and magnitude preservation for every
/// design in the registry.
#[test]
fn prop_signed_mul() {
    let zoo = paper_configs_8bit();
    let mut r = Runner::new("signed-mul", 2000);
    r.run(|g| {
        let m = g.choose(&zoo);
        let a = g.u64_in(0, 255) as i64 * if g.bool() { -1 } else { 1 };
        let b = g.u64_in(0, 255) as i64 * if g.bool() { -1 } else { 1 };
        let s = signed_mul(m.as_ref(), a, b);
        let mag = m.mul(a.unsigned_abs(), b.unsigned_abs()) as i64;
        if s.unsigned_abs() != mag.unsigned_abs() {
            return Err(format!("{}: |{a}*{b}| mismatch", m.name()));
        }
        if s != 0 && (s < 0) != ((a < 0) ^ (b < 0)) {
            return Err(format!("{}: sign of {a}*{b} wrong", m.name()));
        }
        Ok(())
    });
}

/// DRUM's unbiasing: over random operand windows the signed error is
/// centred (sampled mean within a small band).
#[test]
fn prop_drum_unbiased_sampled() {
    use ::scaletrim::util::rng::Xoshiro256;
    let d = Drum::new(8, 4);
    let mut rng = Xoshiro256::seed_from_u64(99);
    let mut sum = 0f64;
    let n = 200_000;
    for _ in 0..n {
        let a = rng.gen_operand(8);
        let b = rng.gen_operand(8);
        sum += d.mul(a, b) as f64 - (a * b) as f64;
    }
    let mean = sum / n as f64;
    assert!(mean.abs() < 160.0, "sampled mean error {mean} not centred");
}
