//! Per-rule fixtures for the project lint engine: every rule gets a
//! positive (fires) and a negative (stays quiet) case, plus the pragma
//! round-trip — suppression on the same line and the line above, and
//! the three stale-pragma failure modes. These run `check_sources` on
//! in-memory sources, so they pin the engine's behaviour independent of
//! the repo tree (`tests/lint_clean.rs` covers the tree itself).

use scaletrim::analysis::{check_sources, Finding, Rule};

fn lint_one(path: &str, src: &str) -> Vec<Finding> {
    check_sources(&[(path, src)])
}

fn rule_names(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule.name()).collect()
}

// -------------------------------------------------------------- R1

#[test]
fn shift_unguarded_fires_on_computed_amount() {
    let src = "fn f(x: u64, k: u32) -> u64 {\n    x << k\n}\n";
    let f = lint_one("multipliers/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["shift-unguarded"], "{f:?}");
    assert_eq!(f[0].line, 2);
    assert!(f[0].message.contains("`k`"), "{}", f[0].message);
}

#[test]
fn shift_guarded_by_debug_assert_is_quiet() {
    let src = "fn f(x: u64, k: u32) -> u64 {\n    debug_assert!(k < 64);\n    x << k\n}\n";
    assert!(lint_one("multipliers/fix.rs", src).is_empty());
}

#[test]
fn shift_guard_spanning_lines_counts() {
    // rustfmt loves to put the guarded identifier on a continuation line.
    let src = concat!(
        "fn f(x: u64, k: u32) -> u64 {\n",
        "    debug_assert!(\n",
        "        k < 64,\n",
        "    );\n",
        "    x << k\n",
        "}\n",
    );
    assert!(lint_one("simd/fix.rs", src).is_empty());
}

#[test]
fn shift_by_const_or_literal_is_quiet() {
    let src = "fn f(x: u64) -> u64 {\n    (x << SHIFT) + (x << 3)\n}\n";
    assert!(lint_one("lut/fix.rs", src).is_empty());
}

#[test]
fn shift_guard_in_previous_fn_does_not_carry_over() {
    let src = concat!(
        "fn g(k: u32) {\n",
        "    debug_assert!(k < 64);\n",
        "}\n",
        "fn f(x: u64, k: u32) -> u64 {\n",
        "    x << k\n",
        "}\n",
    );
    let f = lint_one("nn/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["shift-unguarded"], "{f:?}");
}

#[test]
fn shift_outside_kernel_dirs_is_quiet() {
    let src = "fn f(x: u64, k: u32) -> u64 {\n    x << k\n}\n";
    assert!(lint_one("report/fix.rs", src).is_empty());
}

// -------------------------------------------------------------- R2

#[test]
fn no_panic_fires_on_unwrap_expect_and_panics() {
    let src = concat!(
        "fn f(x: Option<u32>) -> u32 {\n",
        "    let a = x.unwrap();\n",
        "    let b = x.expect(\"b\");\n",
        "    if a > b { panic!(\"no\") }\n",
        "    todo!()\n",
        "}\n",
    );
    let f = lint_one("obs/fix.rs", src);
    assert_eq!(
        rule_names(&f),
        vec!["no-panic", "no-panic", "no-panic", "no-panic"],
        "{f:?}"
    );
}

#[test]
fn no_panic_exempts_main_and_tests_and_strings() {
    let main = "fn main() {\n    run().unwrap();\n}\n";
    assert!(lint_one("main.rs", main).is_empty());
    let test = concat!(
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    #[test]\n",
        "    fn t() {\n",
        "        x().unwrap();\n",
        "    }\n",
        "}\n",
    );
    assert!(lint_one("obs/fix.rs", test).is_empty());
    let s = "fn f() -> &'static str {\n    \"call .unwrap() at your peril\"\n}\n";
    assert!(lint_one("obs/fix.rs", s).is_empty());
}

// -------------------------------------------------------------- R3

#[test]
fn raw_lock_fires_anywhere() {
    let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
    let f = lint_one("report/fix.rs", src);
    // The unwrap also trips no-panic; the raw-lock finding is the
    // specific one that names the helper to use instead.
    assert!(rule_names(&f).contains(&"raw-lock"), "{f:?}");
}

#[test]
fn poison_safe_helper_is_quiet() {
    let src = concat!(
        "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n",
        "    *crate::util::sync::lock_unpoisoned(m)\n",
        "}\n",
    );
    assert!(lint_one("report/fix.rs", src).is_empty());
}

// -------------------------------------------------------------- R4

#[test]
fn narrow_cast_fires_without_mask_or_guard() {
    let src = "fn f(x: u32) -> u8 {\n    x as u8\n}\n";
    let f = lint_one("simd/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["narrow-cast"], "{f:?}");
    assert!(f[0].message.contains("as u8"), "{}", f[0].message);
}

#[test]
fn narrow_cast_with_mask_clamp_shift_or_assert_is_quiet() {
    for src in [
        "fn f(x: u32) -> u8 {\n    (x & 0xff) as u8\n}\n",
        "fn f(x: u32) -> u8 {\n    x.min(255) as u8\n}\n",
        "fn f(x: u32) -> u8 {\n    x.clamp(0, 255) as u8\n}\n",
        "fn f(x: u32) -> u8 {\n    (x >> 24) as u8\n}\n",
        "fn f(x: u32) -> u8 {\n    debug_assert!(x < 256);\n    x as u8\n}\n",
    ] {
        assert!(lint_one("nn/fix.rs", src).is_empty(), "{src}");
    }
}

#[test]
fn narrow_cast_outside_arith_dirs_is_quiet() {
    let src = "fn f(x: u32) -> u8 {\n    x as u8\n}\n";
    assert!(lint_one("coordinator/fix.rs", src).is_empty());
}

// -------------------------------------------------------------- R5

#[test]
fn obs_names_fires_on_inline_literals() {
    let src = concat!(
        "fn f(r: &Registry) {\n",
        "    r.counter(\"my_total\", &[]).inc();\n",
        "    let _s = span(\"ad.hoc\");\n",
        "}\n",
    );
    let f = lint_one("coordinator/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["obs-names", "obs-names"], "{f:?}");
}

#[test]
fn obs_names_exempts_the_names_table_and_constants() {
    let table = concat!(
        "pub const X: &str = \"my_total\";\n",
        "fn f(r: &Registry) {\n",
        "    r.counter(\"my_total\", &[]).inc();\n",
        "}\n",
    );
    assert!(lint_one("obs/names.rs", table).is_empty());
    let via_const = "fn f(r: &Registry) {\n    r.counter(metric::X, &[]).inc();\n}\n";
    assert!(lint_one("coordinator/fix.rs", via_const).is_empty());
}

// -------------------------------------------------------------- R6

#[test]
fn kernel_loop_io_fires_inside_loops() {
    let src = concat!(
        "fn f(n: usize) {\n",
        "    for i in 0..n {\n",
        "        println!(\"{i}\");\n",
        "    }\n",
        "    while n > 0 {\n",
        "        let _t = Instant::now();\n",
        "    }\n",
        "}\n",
    );
    let f = lint_one("workloads/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["kernel-loop-io", "kernel-loop-io"], "{f:?}");
}

#[test]
fn io_outside_the_loop_body_is_quiet() {
    let src = concat!(
        "fn f(n: usize) {\n",
        "    let t0 = Instant::now();\n",
        "    for i in 0..n {\n",
        "        work(i);\n",
        "    }\n",
        "    println!(\"{:?}\", t0.elapsed());\n",
        "}\n",
    );
    assert!(lint_one("workloads/fix.rs", src).is_empty());
}

#[test]
fn loop_body_opening_on_a_later_line_is_tracked() {
    let src = "fn f(n: usize) {\n    for i in\n        0..n\n    {\n        dbg!(i);\n    }\n}\n";
    let f = lint_one("multipliers/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["kernel-loop-io"], "{f:?}");
}

// -------------------------------------------------------------- R7

#[test]
fn unsafe_token_fires_everywhere() {
    let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let f = lint_one("report/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["forbid-unsafe"], "{f:?}");
}

#[test]
fn lib_rs_must_carry_the_forbid_attribute() {
    let bare = "pub mod util;\n";
    let f = lint_one("lib.rs", bare);
    assert_eq!(rule_names(&f), vec!["forbid-unsafe"], "{f:?}");
    assert!(f[0].message.contains("crate root"), "{}", f[0].message);
    let good = "#![forbid(unsafe_code)]\npub mod util;\n";
    assert!(lint_one("lib.rs", good).is_empty());
    // The attribute requirement binds to lib.rs only — other files in a
    // set without lib.rs don't inherit it.
    assert!(lint_one("util/fix.rs", "pub fn f() {}\n").is_empty());
}

// ------------------------------------------------------ pragmas

#[test]
fn trailing_pragma_suppresses_its_own_line() {
    let src = concat!(
        "fn f(m: &M) -> u32 {\n",
        "    *m.lock().unwrap() // lint:allow(raw-lock, no-panic): ",
        "startup-only, poisoning impossible here\n",
        "}\n",
    );
    assert!(lint_one("report/fix.rs", src).is_empty());
}

#[test]
fn standalone_pragma_suppresses_the_next_line() {
    let src = concat!(
        "fn f(x: Option<u32>) -> u32 {\n",
        "    // lint:allow(no-panic): checked non-empty by the caller's contract\n",
        "    x.unwrap()\n",
        "}\n",
    );
    assert!(lint_one("obs/fix.rs", src).is_empty());
}

#[test]
fn pragma_on_the_wrong_line_suppresses_nothing() {
    let src = concat!(
        "fn f(x: Option<u32>) -> u32 {\n",
        "    // lint:allow(no-panic): two lines above the site, too far\n",
        "\n",
        "    x.unwrap()\n",
        "}\n",
    );
    let f = lint_one("obs/fix.rs", src);
    let names = rule_names(&f);
    assert!(names.contains(&"no-panic"), "{f:?}");
    assert!(names.contains(&"stale-pragma"), "{f:?}");
}

#[test]
fn pragma_without_reason_is_stale() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    // lint:allow(no-panic)\n    x.unwrap()\n}\n";
    let f = lint_one("obs/fix.rs", src);
    // The finding is still suppressed, but the reasonless pragma is
    // itself reported — suppressions must say why.
    assert_eq!(rule_names(&f), vec!["stale-pragma"], "{f:?}");
    assert!(f[0].message.contains("reason"), "{}", f[0].message);
}

#[test]
fn pragma_with_unknown_rule_is_stale() {
    let src = "fn f() {\n    // lint:allow(bogus-rule): not a rule we have\n    work();\n}\n";
    let f = lint_one("obs/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["stale-pragma"], "{f:?}");
    assert!(f[0].message.contains("bogus-rule"), "{}", f[0].message);
}

#[test]
fn pragma_suppressing_nothing_is_stale() {
    let src = concat!(
        "fn f() {\n",
        "    // lint:allow(no-panic): there is nothing here any more\n",
        "    work();\n",
        "}\n",
    );
    let f = lint_one("obs/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["stale-pragma"], "{f:?}");
    assert!(f[0].message.contains("suppresses nothing"), "{}", f[0].message);
}

// ------------------------------------------------------ plumbing

#[test]
fn rule_names_round_trip() {
    for r in Rule::ALL {
        assert_eq!(Rule::from_name(r.name()), Some(r), "{r:?}");
    }
    assert_eq!(Rule::from_name("not-a-rule"), None);
}

#[test]
fn findings_render_compiler_style_and_sort_stably() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let f = lint_one("obs/fix.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].render(), "obs/fix.rs:2: [no-panic] unwrap() in library code");
    // Multi-file: results come back sorted by path then line.
    let a = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let b = concat!(
        "fn g(x: Option<u32>) -> u32 {\n",
        "    x.unwrap()\n",
        "}\n",
        "fn h(x: Option<u32>) -> u32 {\n",
        "    x.unwrap()\n",
        "}\n",
    );
    let all = check_sources(&[("zeta/b.rs", b), ("alpha/a.rs", a)]);
    let keys: Vec<(String, usize)> = all.iter().map(|f| (f.path.clone(), f.line)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
    assert_eq!(keys[0].0, "alpha/a.rs");
}

// -------------------------------------------------------------- lexer line sync
//
// Regressions for the two historical line-desync bugs: a raw string or
// a nested block comment spanning lines must neither leak its contents
// into the code stream nor shift the line attribution of real findings
// after it.

#[test]
fn multiline_raw_string_keeps_line_numbers_in_sync() {
    // Lines 3–4 live inside the raw string: the shift and the raw lock
    // in there are prose, not code. The real violation is on line 6 and
    // must be reported there, not at an offset.
    let src = concat!(
        "fn f(x: u64, k: u32) -> u64 {\n",
        "    let doc = r#\"\n",
        "        x << k and lock().unwrap() are not code\n",
        "    \"#;\n",
        "    let _ = doc;\n",
        "    x << k\n",
        "}\n",
    );
    let f = lint_one("multipliers/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["shift-unguarded"], "{f:?}");
    assert_eq!(f[0].line, 6, "finding shifted — raw string desynced the lexer");
}

#[test]
fn raw_string_closes_only_on_matching_hash_count() {
    // The `"#` on line 3 is NOT a terminator for an `r##` string; if the
    // lexer bit on it, the rest of the literal would lex as code.
    let src = concat!(
        "fn f(x: u64, k: u32) -> u64 {\n",
        "    let s = r##\"\n",
        "        \"# not a terminator: lock().unwrap()\n",
        "    \"##;\n",
        "    let _ = s;\n",
        "    x << k\n",
        "}\n",
    );
    let f = lint_one("lut/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["shift-unguarded"], "{f:?}");
    assert_eq!(f[0].line, 6);
}

#[test]
fn nested_block_comment_keeps_line_numbers_in_sync() {
    // Rust block comments nest: the `*/` on line 3 closes only the inner
    // comment, so line 4 is still commented out. A flat-depth lexer
    // would lex line 4 as code (raw-lock + no-panic findings) and could
    // misattribute the real shift on line 6.
    let src = concat!(
        "fn f(x: u64, k: u32) -> u64 {\n",
        "    /* outer /* inner\n",
        "       x << k stays commented */\n",
        "       still outer: lock().unwrap()\n",
        "    */\n",
        "    x << k\n",
        "}\n",
    );
    let f = lint_one("simd/fix.rs", src);
    assert_eq!(rule_names(&f), vec!["shift-unguarded"], "{f:?}");
    assert_eq!(f[0].line, 6);
}
