//! Property test (the obs plane's headline guarantee): registry shards
//! merged in *any* order reproduce the single-registry quantiles
//! bit-for-bit. The sketch's integer bins make histogram merging exactly
//! commutative and associative, so a scrape over N coordinator shards can
//! never drift from what one global registry would have reported.

use ::scaletrim::obs::{Registry, Snapshot};
use ::scaletrim::util::prop::Runner;

#[test]
fn shard_merge_quantiles_are_bit_identical_in_any_order() {
    let mut r = Runner::new("obs-shard-merge-bit-identical", 60);
    r.run(|g| {
        let n_shards = g.usize_in(2, 6);
        let whole = Registry::new();
        let shards: Vec<Registry> = (0..n_shards).map(|_| Registry::new()).collect();
        let hw = whole.histogram("lat_seconds", &[]);
        let cw = whole.counter("events_total", &[]);
        let gw = whole.gauge("depth", &[]);

        // Spray samples over the shards: wide dynamic range (microseconds
        // to kiloseconds) so many octaves of the sketch participate.
        let n_samples = g.usize_in(1, 400);
        for _ in 0..n_samples {
            let shard = g.usize_in(0, n_shards - 1);
            let v = g.u64_in(1, 1_000_000_000) as f64 / 1e6;
            hw.record(v);
            cw.inc();
            gw.add(1);
            shards[shard].histogram("lat_seconds", &[]).record(v);
            shards[shard].counter("events_total", &[]).inc();
            shards[shard].gauge("depth", &[]).add(1);
        }

        // Merge the shard snapshots in a random permutation of the order.
        let mut order: Vec<usize> = (0..n_shards).collect();
        for i in 0..n_shards {
            let j = g.usize_in(i, n_shards - 1);
            order.swap(i, j);
        }
        let mut merged = Snapshot::default();
        for &i in &order {
            merged.merge(&shards[i].snapshot());
        }

        let reference = whole.snapshot();
        let id = reference.hists.keys().next().unwrap();
        let (m, rf) = (&merged.hists[id], &reference.hists[id]);
        if m.count() != rf.count() {
            return Err(format!("count {} != {}", m.count(), rf.count()));
        }
        for q in [50.0, 99.0, 99.9] {
            let (a, b) = (m.quantile(q), rf.quantile(q));
            if a.to_bits() != b.to_bits() {
                return Err(format!("p{q}: merged {a} != reference {b} (order {order:?})"));
            }
        }
        // min/max are exact set extrema — order-independent, bit-for-bit.
        if m.min().to_bits() != rf.min().to_bits() || m.max().to_bits() != rf.max().to_bits() {
            return Err("min/max drifted under merge".into());
        }
        // Sums are f64 additions, so only order-tolerant agreement holds.
        if (m.sum - rf.sum).abs() > 1e-9 * rf.sum.abs().max(1.0) {
            return Err(format!("sum {} != {}", m.sum, rf.sum));
        }
        if merged.counter_sum("events_total") != n_samples as u64 {
            return Err(format!(
                "counter lost events: {} != {n_samples}",
                merged.counter_sum("events_total")
            ));
        }
        let depth: i64 = merged
            .gauges
            .iter()
            .filter(|(k, _)| k.name == "depth")
            .map(|(_, v)| v)
            .sum();
        if depth != n_samples as i64 {
            return Err(format!("gauge lost events: {depth} != {n_samples}"));
        }
        Ok(())
    });
}
