//! End-to-end tests of the network serving plane over loopback: wire
//! round trips, explicit overload, per-connection rate limiting, lane
//! panic containment across the wire, graceful drain, and the
//! wire-conservation invariant on the final snapshot (no request is ever
//! silently dropped).

use scaletrim::coordinator::{Backend, MockBackend};
use scaletrim::multipliers::{ApproxMultiplier, Exact, ScaleTrim};
use scaletrim::net::{
    healthz, AdmissionPolicy, Client, ClientConfig, Response, ServeConfig, Server, WireErrorKind,
};
use scaletrim::obs::{self, names};
use std::sync::Arc;
use std::time::Duration;

fn test_client_cfg() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_secs(1),
        io_timeout: Duration::from_secs(10),
        retries: 5,
        backoff: Duration::from_millis(50),
    }
}

#[test]
fn wire_round_trip_hello_ping_submit_stats_healthz() {
    let exact = Exact::new(8);
    let st = ScaleTrim::new(8, 3, 4);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 2,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        Ok(Arc::new(MockBackend::new(4, 4)) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr, &test_client_cfg()).unwrap();
    let (shards, img, labels) = c.hello().unwrap();
    assert_eq!(shards, 2);
    assert_eq!(img, 4, "mock shape is 1x2x2");
    assert_eq!(labels, vec!["Exact8".to_string(), "scaleTRIM(3,4)".to_string()]);
    c.ping().unwrap();

    // Routing semantics survive the wire: logit[k] is hot iff
    // k == pixels[0] % classes.
    for label in &labels {
        let spec = label.parse().unwrap();
        match c.submit(&spec, &[7, 1, 2, 3]).unwrap() {
            Response::Reply { class, logits, .. } => {
                assert_eq!(class, 7 % 4, "lane {label}");
                assert_eq!(logits.len(), 4);
            }
            other => panic!("expected a reply on lane {label}, got {other:?}"),
        }
    }

    let stats = c.stats().unwrap();
    assert_eq!(
        stats.get("schema").and_then(scaletrim::util::json::Json::as_str),
        Some("scaletrim-wire/v1")
    );
    assert_eq!(
        stats.get("requests").and_then(scaletrim::util::json::Json::as_f64),
        Some(2.0)
    );

    // The healthz endpoint serves the merged SLO line plus the full
    // Prometheus exposition on the same port.
    let body = healthz(&addr, &test_client_cfg()).unwrap();
    assert!(body.starts_with("HTTP/1.0 200 OK"), "{body}");
    assert!(body.contains("service latency:"), "{body}");
    assert!(body.contains("net_request_latency_seconds"), "{body}");

    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum(names::metric::NET_REQUESTS_TOTAL), 2);
    assert_eq!(snap.counter_sum(names::metric::NET_RESPONSES_OK_TOTAL), 2);
}

#[test]
fn overload_answers_explicit_wire_error_and_conserves() {
    // One admission slot, a slow serialized backend: a pipelined burst
    // must shed most submits with an explicit `overloaded` answer — and
    // every single one of the 50 must still be answered.
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 1,
        admission: AdmissionPolicy {
            queue_depth: 1,
            ..AdmissionPolicy::default()
        },
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        Ok(Arc::new(MockBackend::new(1, 2).with_work(2_000_000).serialized()) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let client = Client::connect(&addr, &test_client_cfg()).unwrap();
    let (mut tx, mut rx) = client.into_split().unwrap();
    let spec = exact.spec();
    const N: usize = 50;
    for _ in 0..N {
        tx.send_submit(&spec, &[9, 9, 9, 9]).unwrap();
    }
    let (mut ok, mut overloaded) = (0u64, 0u64);
    for _ in 0..N {
        match rx.recv_response().unwrap() {
            Response::Reply { .. } => ok += 1,
            Response::Error {
                kind: WireErrorKind::Overloaded,
                ..
            } => overloaded += 1,
            other => panic!("unexpected answer under overload: {other:?}"),
        }
    }
    assert!(ok >= 1, "at least the first submit must be admitted");
    assert!(overloaded >= 1, "a 50-deep burst into 1 slot must shed");
    assert_eq!(ok + overloaded, N as u64, "all 50 answered — no silent drop");

    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum(names::metric::NET_REQUESTS_TOTAL), ok);
    assert_eq!(snap.counter_sum(names::metric::NET_RESPONSES_OK_TOTAL), ok);
    assert_eq!(snap.counter_sum(names::metric::NET_OVERLOADED_TOTAL), overloaded);
}

#[test]
fn rate_limit_sheds_past_burst() {
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 1,
        admission: AdmissionPolicy {
            queue_depth: 64,
            rate_per_s: 1.0,
            burst: 1.0,
        },
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        Ok(Arc::new(MockBackend::new(1, 2)) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr, &test_client_cfg()).unwrap();
    let spec = exact.spec();
    let (mut ok, mut limited) = (0u64, 0u64);
    for _ in 0..5 {
        match c.submit(&spec, &[1, 1, 1, 1]).unwrap() {
            Response::Reply { .. } => ok += 1,
            Response::Error {
                kind: WireErrorKind::RateLimited,
                ..
            } => limited += 1,
            other => panic!("unexpected answer: {other:?}"),
        }
    }
    assert_eq!(ok, 1, "burst of 1 admits exactly the first submit");
    assert_eq!(limited, 4, "the rest shed with an explicit rate_limited");

    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum(names::metric::NET_RATE_LIMITED_TOTAL), 4);
}

#[test]
fn lane_panic_becomes_typed_lane_failed_over_the_wire() {
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 1,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        // Every second infer call panics; the lane must answer the batch
        // with `lane_failed` and keep serving.
        Ok(Arc::new(MockBackend::new(1, 2).with_panics(2)) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr, &test_client_cfg()).unwrap();
    let spec = exact.spec();
    let (mut ok, mut failed) = (0u64, 0u64);
    for _ in 0..4 {
        match c.submit(&spec, &[3, 0, 0, 0]).unwrap() {
            Response::Reply { .. } => ok += 1,
            Response::Error {
                kind: WireErrorKind::LaneFailed,
                message,
                ..
            } => {
                assert!(message.contains("injected lane panic"), "{message}");
                failed += 1;
            }
            other => panic!("unexpected answer: {other:?}"),
        }
    }
    assert_eq!(ok, 2, "odd calls succeed");
    assert_eq!(failed, 2, "even calls fail typed, lane survives");

    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum(names::metric::NET_RESPONSES_ERROR_TOTAL), 2);
    assert!(snap.counter_sum(names::metric::COORD_LANE_FAILURES_TOTAL) >= 2);
}

#[test]
fn graceful_drain_completes_inflight_and_sheds_new_connections() {
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 1,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        Ok(Arc::new(MockBackend::new(1, 2).with_work(500_000).serialized()) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    // Admit one slow request, then begin the drain while it is in flight.
    let client = Client::connect(&addr, &test_client_cfg()).unwrap();
    let (mut tx, mut rx) = client.into_split().unwrap();
    tx.send_submit(&exact.spec(), &[5, 5, 5, 5]).unwrap();
    std::thread::sleep(Duration::from_millis(200)); // let the admit land
    server.begin_drain();

    // New connections are shed at the front door with one explicit
    // Overloaded frame — read it without sending anything.
    let mut late = Client::connect(&addr, &test_client_cfg()).unwrap();
    match late.recv_response().unwrap() {
        Response::Error {
            kind: WireErrorKind::Overloaded,
            message,
            ..
        } => assert!(message.contains("draining"), "{message}"),
        other => panic!("draining server must shed new connections, got {other:?}"),
    }

    // The in-flight request still completes — drain is graceful.
    match rx.recv_response().unwrap() {
        Response::Reply { class, .. } => assert_eq!(class, 5 % 2),
        other => panic!("in-flight request must complete, got {other:?}"),
    }

    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
    assert_eq!(snap.counter_sum(names::metric::NET_REQUESTS_TOTAL), 1);
    assert_eq!(snap.counter_sum(names::metric::NET_RESPONSES_OK_TOTAL), 1);
    assert!(snap.counter_sum(names::metric::NET_OVERLOADED_TOTAL) >= 1);
}

#[test]
fn remote_shutdown_frame_begins_the_drain() {
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        shards: 1,
        ..ServeConfig::default()
    };
    let server = Server::start(cfg, &configs, |_s| {
        Ok(Arc::new(MockBackend::new(1, 2)) as Arc<dyn Backend>)
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr, &test_client_cfg()).unwrap();
    assert!(!server.is_draining());
    c.shutdown_server().unwrap();
    assert!(server.is_draining(), "a wire shutdown frame must begin drain");
    let snap = server.shutdown();
    obs::check_invariants(&snap).unwrap();
}
