//! The repo-wide lint gate: the committed source tree must be clean
//! under the project lint engine. This is the same check `scaletrim
//! lint` runs in CI, but as a plain `cargo test` so a violation shows up
//! in the tightest local loop, with every finding printed
//! compiler-style before the assertion fires.

use scaletrim::analysis::lint_tree;
use std::path::Path;

#[test]
fn source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint_tree(&root).expect("linting the source tree");
    for f in &findings {
        eprintln!("{}", f.render());
    }
    assert!(
        findings.is_empty(),
        "{} lint finding(s) in the committed tree — run `scaletrim lint` \
         (or see the lines above); suppress only with a reasoned pragma",
        findings.len()
    );
}

#[test]
fn tree_walk_sees_the_whole_crate() {
    // Guard against the walker silently missing directories: the tree
    // has well over this many .rs files, spread across every layer.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut count = 0usize;
    let mut stack = vec![root.clone()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir).expect("read_dir") {
            let p = entry.expect("entry").path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                count += 1;
            }
        }
    }
    assert!(count > 40, "only {count} .rs files found under {}", root.display());
}
