//! Coordinator integration: routing, batching, occupancy, failure
//! isolation, and (when artifacts exist) end-to-end PJRT serving.

use ::scaletrim::coordinator::{BatchPolicy, Coordinator, MockBackend, PjrtBackend, PureRustBackend};
use ::scaletrim::multipliers::{ApproxMultiplier, Exact, ScaleTrim};
use ::scaletrim::nn::{Dataset, QuantizedCnn, QuantizedWeights};
use ::scaletrim::runtime::{find_artifacts_dir, ArtifactSet};
use std::sync::Arc;
use std::time::Duration;

fn policy(batch: usize) -> BatchPolicy {
    BatchPolicy {
        max_batch: batch,
        max_wait: Duration::from_millis(2),
    }
}

#[test]
fn high_load_fills_batches() {
    let backend = Arc::new(MockBackend::new(16, 4));
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let coord = Coordinator::new(backend, &configs, policy(16));
    let mut rx = Vec::new();
    for _ in 0..512 {
        rx.push(coord.submit("Exact8", vec![1, 2, 3, 4]).unwrap().1);
    }
    for r in rx {
        assert!(r.recv().unwrap().error.is_none());
    }
    let m = coord.metrics();
    let occ = m.mean_occupancy();
    assert!(occ > 8.0, "occupancy {occ} too low under saturation");
    assert_eq!(m.responses(), 512);
}

#[test]
fn lanes_are_isolated() {
    // A failing lane must not poison the healthy lane.
    let backend = Arc::new(MockBackend::new(4, 4).with_failures(1)); // every call fails
    let exact = Exact::new(8);
    let st = ScaleTrim::new(8, 3, 4);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st];
    let coord = Coordinator::new(backend, &configs, policy(4));
    let p = coord.infer_blocking("Exact8", vec![0; 4]).unwrap();
    assert!(p.error.is_some());
    // Lane threads are still alive; a second submit still round-trips.
    let p2 = coord.infer_blocking("scaleTRIM(3,4)", vec![0; 4]).unwrap();
    assert!(p2.error.is_some());
}

#[test]
fn pure_rust_backend_serves_real_model() {
    let Ok(dir) = find_artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let Ok(set) = ArtifactSet::resolve(&dir, "lenet") else {
        return;
    };
    let data = Dataset::load(&set.dataset).unwrap();
    let cnn = QuantizedCnn::new(QuantizedWeights::load(&set.weights).unwrap());
    let backend = Arc::new(PureRustBackend::new(cnn, 8));
    let exact = Exact::new(8);
    let st = ScaleTrim::new(8, 4, 8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st];
    let coord = Coordinator::new(backend, &configs, policy(8));
    let mut correct = 0;
    let n = 64;
    for i in 0..n {
        let p = coord
            .infer_blocking("scaleTRIM(4,8)", data.image(i).to_vec())
            .unwrap();
        assert!(p.error.is_none());
        if p.class == data.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct as f64 / n as f64 > 0.85, "accuracy {correct}/{n}");
}

#[test]
fn pjrt_backend_end_to_end() {
    let Ok(dir) = find_artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let Ok(set) = ArtifactSet::resolve(&dir, "lenet") else {
        return;
    };
    let data = Dataset::load(&set.dataset).unwrap();
    let backend = Arc::new(
        PjrtBackend::spawn(
            set.hlo.to_str().unwrap().to_string(),
            32,
            data.n_classes,
            (data.c, data.h, data.w),
        )
        .expect("pjrt backend"),
    );
    let exact = Exact::new(8);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact];
    let coord = Coordinator::new(backend, &configs, policy(32));
    let mut rx = Vec::new();
    for i in 0..96 {
        rx.push((i, coord.submit("Exact8", data.image(i).to_vec()).unwrap().1));
    }
    let mut correct = 0;
    for (i, r) in rx {
        let p = r.recv().unwrap();
        assert!(p.error.is_none(), "{:?}", p.error);
        if p.class == data.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 85, "pjrt served accuracy {correct}/96");
}
