//! Model checking of the repo's two lock-free protocols with the
//! in-repo bounded interleaving explorer (`analysis::interleave`).
//!
//! Each protocol is expressed as a small sequential model — shared words
//! plus per-thread step programs — and every interleaving is explored:
//!
//! 1. the flight recorder's slot protocol (invalidate seq → write
//!    payload → publish seq, reader re-checks seq around its snapshot),
//!    mirroring `obs::recorder`;
//! 2. the calibration cache's panic-then-retry initialization
//!    (a panicking init leaves the slot empty for the next caller),
//!    mirroring `calib::CalibCache`.
//!
//! For each protocol a deliberately broken variant must be *caught* —
//! the torn read for the recorder, the wedged slot for the cache — so
//! these tests pin both the protocols and the explorer's ability to
//! falsify them.

use scaletrim::analysis::interleave::{explore, Model, Step};

// ---------------------------------------------------------------------
// Flight-recorder slot protocol
// ---------------------------------------------------------------------

/// One recorder slot (seq + a two-word payload), a writer overwriting it
/// with generation 2, and a reader taking a seq-validated snapshot.
///
/// `invalidate_first` selects the real protocol (the writer zeroes `seq`
/// before touching the payload, exactly like `Slot::write` in
/// `obs::recorder`) or the broken one (payload overwritten under a
/// still-valid `seq`, so a concurrent reader can pair half-old,
/// half-new words with an unchanged sequence number).
#[derive(Clone)]
struct RecorderSlot {
    seq: u64,
    w1: u64,
    w2: u64,
    writer_pc: u8,
    reader_pc: u8,
    s1: u64,
    r1: u64,
    r2: u64,
    accepted: Option<(u64, u64)>,
    invalidate_first: bool,
}

impl RecorderSlot {
    fn new(invalidate_first: bool) -> Self {
        // Generation 1 is already published; the writer produces gen 2.
        RecorderSlot {
            seq: 1,
            w1: 1,
            w2: 1,
            writer_pc: 0,
            reader_pc: 0,
            s1: 0,
            r1: 0,
            r2: 0,
            accepted: None,
            invalidate_first,
        }
    }
}

impl Model for RecorderSlot {
    fn thread_count(&self) -> usize {
        2
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid == 0 {
            // Writer. With `invalidate_first` the program is the real
            // one: seq←0, payload, seq←2. Without it the invalidation
            // step is skipped.
            let pc = if self.invalidate_first {
                self.writer_pc
            } else {
                self.writer_pc + 1
            };
            self.writer_pc += 1;
            match pc {
                0 => {
                    self.seq = 0;
                    Step::Progressed
                }
                1 => {
                    self.w1 = 2;
                    Step::Progressed
                }
                2 => {
                    self.w2 = 2;
                    Step::Progressed
                }
                _ => {
                    self.seq = 2;
                    Step::Done
                }
            }
        } else {
            // Reader: s1, payload snapshot, s2; accept iff the sequence
            // number is valid and unchanged around the payload reads.
            self.reader_pc += 1;
            match self.reader_pc {
                1 => {
                    self.s1 = self.seq;
                    Step::Progressed
                }
                2 => {
                    self.r1 = self.w1;
                    Step::Progressed
                }
                3 => {
                    self.r2 = self.w2;
                    Step::Progressed
                }
                _ => {
                    let s2 = self.seq;
                    if self.s1 != 0 && self.s1 == s2 {
                        self.accepted = Some((self.r1, self.r2));
                    }
                    Step::Done
                }
            }
        }
    }

    fn invariant(&self) -> Result<(), String> {
        match self.accepted {
            Some((a, b)) if a != b => Err(format!("torn read accepted: payload ({a}, {b})")),
            _ => Ok(()),
        }
    }
}

#[test]
fn recorder_slot_protocol_admits_no_torn_read() {
    let (violation, stats) = explore(&RecorderSlot::new(true), 32);
    assert!(violation.is_none(), "unexpected: {violation:?}");
    assert!(stats.schedules > 0, "exploration must complete schedules");
    assert!(stats.complete(), "depth bound must not bite");
}

#[test]
fn recorder_without_invalidation_is_caught_torn() {
    let (violation, _) = explore(&RecorderSlot::new(false), 32);
    let v = violation.expect("the torn read must be found");
    assert!(v.message.contains("torn read"), "{}", v.message);
    // The counterexample schedule must replay to the same violation.
    let mut m = RecorderSlot::new(false);
    for &tid in &v.schedule {
        m.step(tid);
    }
    assert!(m.invariant().is_err(), "schedule {:?} must replay", v.schedule);
}

// ---------------------------------------------------------------------
// Calibration-cache panic-then-retry initialization
// ---------------------------------------------------------------------

/// Slot lifecycle of one `CalibCache` key.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum SlotState {
    Empty,
    Building,
    Ready,
}

/// Thread 0's calibration closure panics; thread 1 then computes the
/// value. `clear_on_panic` selects the real contract (the panicking init
/// leaves the slot empty — per-key OnceLock semantics) or the broken one
/// (the slot stays claimed forever, wedging every later caller).
#[derive(Clone)]
struct RetryInit {
    slot: SlotState,
    pc: [u8; 2],
    got: [bool; 2],
    panicked: bool,
    retried: bool,
    clear_on_panic: bool,
}

impl RetryInit {
    fn new(clear_on_panic: bool) -> Self {
        RetryInit {
            slot: SlotState::Empty,
            pc: [0, 0],
            got: [false, false],
            panicked: false,
            retried: false,
            clear_on_panic,
        }
    }
}

impl Model for RetryInit {
    fn thread_count(&self) -> usize {
        2
    }

    fn step(&mut self, tid: usize) -> Step {
        match self.pc[tid] {
            // Acquire: claim an empty slot, use a ready one, wait on a
            // peer's in-flight build.
            0 => match self.slot {
                SlotState::Empty => {
                    self.slot = SlotState::Building;
                    // A claim after a peer's panic is the retry the
                    // cache's `retries()` counter reports.
                    self.retried |= self.panicked;
                    self.pc[tid] = 1;
                    Step::Progressed
                }
                SlotState::Ready => {
                    self.got[tid] = true;
                    self.pc[tid] = 2;
                    Step::Done
                }
                SlotState::Building => Step::Blocked,
            },
            // Build: thread 0's closure panics, thread 1's succeeds.
            1 => {
                if tid == 0 {
                    // The panic unwinds out of the init closure.
                    self.panicked = true;
                    if self.clear_on_panic {
                        self.slot = SlotState::Empty;
                    }
                    self.pc[tid] = 2;
                    Step::Done
                } else {
                    self.slot = SlotState::Ready;
                    self.got[tid] = true;
                    self.pc[tid] = 2;
                    Step::Done
                }
            }
            _ => Step::Done,
        }
    }

    fn invariant(&self) -> Result<(), String> {
        // Thread 1 must always end holding the value; thread 0's panic
        // propagates (it never "gets" the value) but must not stop its
        // peer. Completion itself is watched by the explorer's deadlock
        // detection: in the wedged variant thread 1 blocks forever.
        if self.pc[1] >= 2 && !self.got[1] {
            return Err("thread 1 finished without the calibration value".into());
        }
        // If the value landed after a panic, it can only have come from a
        // fresh claim of the cleared slot — the retry the cache's
        // `retries()` counter reports.
        if self.panicked && self.got[1] && !self.retried {
            return Err("thread 1 got the value without a post-panic retry".into());
        }
        Ok(())
    }
}

#[test]
fn cache_retry_after_panicking_init_completes() {
    let (violation, stats) = explore(&RetryInit::new(true), 32);
    assert!(violation.is_none(), "unexpected: {violation:?}");
    assert!(stats.schedules > 0);
    assert_eq!(stats.truncated, 0);
}

#[test]
fn cache_that_keeps_a_panicked_claim_wedges() {
    let (violation, _) = explore(&RetryInit::new(false), 32);
    let v = violation.expect("the wedged slot must surface as a deadlock");
    assert!(v.message.contains("deadlock"), "{}", v.message);
}

// ---------------------------------------------------------------------
// Depth-bound semantics
// ---------------------------------------------------------------------

/// A single-thread countdown whose invariant breaks after exactly
/// `total` steps: the shortest (and only) counterexample has length
/// `total`, putting it exactly on the edge of the depth bound.
#[derive(Clone)]
struct Countdown {
    left: u8,
}

impl Model for Countdown {
    fn thread_count(&self) -> usize {
        1
    }
    fn step(&mut self, _tid: usize) -> Step {
        if self.left > 0 {
            self.left -= 1;
        }
        if self.left == 0 {
            Step::Done
        } else {
            Step::Progressed
        }
    }
    fn invariant(&self) -> Result<(), String> {
        if self.left == 0 {
            Err("countdown reached the corrupt state".to_string())
        } else {
            Ok(())
        }
    }
}

/// A counterexample exactly at the bound is found with nothing
/// truncated; a bound one short of it misses the violation but *says
/// so* — `truncated` is counted, never silent, and `Stats::complete`
/// flips, so a clean result under a too-small bound cannot be read as
/// a proof.
#[test]
fn counterexample_exactly_at_the_depth_bound() {
    const D: usize = 6;
    let model = Countdown { left: D as u8 };

    let (violation, stats) = explore(&model, D);
    let v = violation.expect("bound == counterexample length must find it");
    assert_eq!(v.schedule.len(), D, "shortest counterexample is exactly D");
    assert!(v.message.contains("corrupt state"), "{}", v.message);
    assert!(
        stats.complete(),
        "the violating branch ends the search before any truncation"
    );

    let (violation, stats) = explore(&model, D - 1);
    assert!(violation.is_none(), "one step short must miss it");
    assert_eq!(stats.truncated, 1, "the cut branch is counted, not silent");
    assert!(!stats.complete(), "a truncated run must not read as a proof");
    assert_eq!(stats.schedules, 0);
}
