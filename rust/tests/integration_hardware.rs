//! Hardware-model integration: calibration quality against Table 4 and the
//! cross-family cost relationships the paper's evaluation relies on.

use ::scaletrim::hardware::{estimate, paper_reference};
use ::scaletrim::multipliers::*;

#[test]
fn every_config_estimable_at_both_widths() {
    for m in paper_configs_8bit() {
        let e = estimate(m.as_ref());
        assert!(e.area_um2 > 0.0 && e.pdp_fj > 0.0, "{}", e.name);
    }
    for m in paper_configs_16bit() {
        let e = estimate(m.as_ref());
        assert!(e.area_um2 > 0.0, "{}", e.name);
    }
}

#[test]
fn scaletrim_rows_track_table4() {
    // Per-row band after self-calibration: no scaleTRIM row may deviate
    // from the paper by more than ~1.6x on any metric.
    for h in 2..=7u32 {
        for m in [0u32, 4, 8] {
            let st = ScaleTrim::new(8, h, m);
            let e = estimate(&st);
            let (_, pd, pa, _, ppdp) = paper_reference(&st.spec()).unwrap();
            for (metric, ours, paper) in [
                ("area", e.area_um2, pa),
                ("delay", e.delay_ns, pd),
                ("pdp", e.pdp_fj, ppdp),
            ] {
                let ratio = ours / paper;
                assert!(
                    (0.55..1.8).contains(&ratio),
                    "ST({h},{m}) {metric}: {ours:.1} vs paper {paper:.1} (ratio {ratio:.2})"
                );
            }
        }
    }
}

#[test]
fn cost_monotone_in_knobs() {
    // Area/PDP grow with h and with M; delay grows with h.
    let a = estimate(&ScaleTrim::new(8, 3, 0));
    let b = estimate(&ScaleTrim::new(8, 3, 8));
    let c = estimate(&ScaleTrim::new(8, 6, 8));
    assert!(b.area_um2 > a.area_um2);
    assert!(c.area_um2 > b.area_um2);
    assert!(c.delay_ns > a.delay_ns);
    assert!(c.pdp_fj > b.pdp_fj);
}

#[test]
fn family_relationships() {
    // Sec. IV-B: TOSAM's LUT LOD is faster; scaleTRIM wins area/power.
    let st = estimate(&ScaleTrim::new(8, 5, 8));
    let tosam = estimate(&Tosam::new(8, 1, 5));
    assert!(tosam.delay_ns < st.delay_ns, "TOSAM should be faster");
    // Sec. IV-D / Table 3: piecewise costs more area than scaleTRIM at the
    // same h (two constants per segment + a real multiplier).
    let pw = estimate(&PiecewiseLinear::new(8, 4, 4));
    let st48 = estimate(&ScaleTrim::new(8, 4, 8));
    assert!(
        pw.area_um2 > st48.area_um2,
        "piecewise {:.1} should out-cost scaleTRIM {:.1}",
        pw.area_um2,
        st48.area_um2
    );
    // Exact array multiplier costs more than any truncating design.
    let exact = estimate(&Exact::new(8));
    assert!(exact.area_um2 > st.area_um2);
    assert!(exact.pdp_fj > st48.pdp_fj);
}

#[test]
fn wider_operands_cost_more() {
    let pairs: Vec<(Box<dyn ApproxMultiplier>, Box<dyn ApproxMultiplier>)> = vec![
        (
            Box::new(ScaleTrim::new(8, 5, 8)),
            Box::new(ScaleTrim::new(16, 5, 8)),
        ),
        (Box::new(Drum::new(8, 5)), Box::new(Drum::new(16, 5))),
    ];
    for (mk8, mk16) in &pairs {
        let e8 = estimate(mk8.as_ref());
        let e16 = estimate(mk16.as_ref());
        assert!(e16.area_um2 > e8.area_um2, "{}", e16.name);
        assert!(e16.pdp_fj > e8.pdp_fj, "{}", e16.name);
    }
}

#[test]
fn pdp_equals_power_times_delay() {
    for m in paper_configs_8bit().iter().take(10) {
        let e = estimate(m.as_ref());
        assert!(
            (e.pdp_fj - e.power_uw * e.delay_ns).abs() < 1e-6,
            "{}: PDP {} != P*D {}",
            e.name,
            e.pdp_fj,
            e.power_uw * e.delay_ns
        );
    }
}
