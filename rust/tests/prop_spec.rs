//! The typed-identity-plane acceptance suite: `DesignSpec` label and JSON
//! round trips over the full 8- and 16-bit zoos, O(1) construction
//! equivalence against the registries, and rejection of malformed labels
//! (in-repo prop rig, `util::prop`, for the randomized slice).

use ::scaletrim::multipliers::{
    paper_configs_16bit, paper_configs_8bit, ApproxMultiplier, DesignSpec,
};
use ::scaletrim::util::json::Json;
use ::scaletrim::util::prop::Runner;

/// Deterministic full-zoo round trip: for every registered spec,
/// `from_str(spec.to_string()) == spec` and `build(bits).name() ==
/// spec.to_string()` — the ISSUE-4 acceptance property, exhaustively.
#[test]
fn spec_round_trips_exhaustively_over_both_zoos() {
    for bits in [8u32, 16] {
        let specs = DesignSpec::enumerate(bits).unwrap();
        assert!(!specs.is_empty());
        for spec in specs {
            let label = spec.to_string();
            // Label round trip.
            let parsed: DesignSpec = label.parse().unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(parsed, spec, "{label}");
            // Construction round trip, O(1), no zoo materialisation.
            let built = spec.build(bits).unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(built.name(), label);
            assert_eq!(built.spec(), spec);
            assert_eq!(built.bits(), bits);
            // JSON round trip through the wire form.
            let wire = spec.to_json().to_string();
            let back = DesignSpec::from_json(&Json::parse(&wire).unwrap()).unwrap();
            assert_eq!(back, spec, "{wire}");
        }
    }
}

/// The registries themselves are regenerated from `enumerate`, so a
/// spec-built instance and its registry twin agree on identity *and*
/// behaviour (spot-checked over the operand corners).
#[test]
fn spec_built_instances_match_registry_instances() {
    for (bits, zoo) in [(8u32, paper_configs_8bit()), (16, paper_configs_16bit())] {
        let specs = DesignSpec::enumerate(bits).unwrap();
        assert_eq!(zoo.len(), specs.len());
        let probe: Vec<u64> = vec![0, 1, 2, 3, 48, 81, (1 << bits) - 2, (1 << bits) - 1];
        for (m, spec) in zoo.iter().zip(&specs) {
            assert_eq!(m.spec(), *spec);
            let rebuilt = spec.build(bits).unwrap();
            for &a in &probe {
                for &b in &probe {
                    assert_eq!(
                        m.mul(a, b),
                        rebuilt.mul(a, b),
                        "{spec}: registry vs spec-built diverge at {a}*{b}"
                    );
                }
            }
        }
    }
}

/// Randomized slice of the same property (exercises the shrinker path and
/// random label whitespace): any registered spec survives
/// display→parse→build at its width.
#[test]
fn prop_random_spec_round_trip() {
    let specs8 = DesignSpec::enumerate(8).unwrap();
    let specs16 = DesignSpec::enumerate(16).unwrap();
    let mut r = Runner::new("spec-round-trip", 500);
    r.run(|g| {
        let (bits, specs) = if g.bool() { (8u32, &specs8) } else { (16u32, &specs16) };
        let spec = *g.choose(specs);
        let label = if g.bool() {
            format!("  {spec}  ") // FromStr trims
        } else {
            spec.to_string()
        };
        let parsed: DesignSpec = label
            .parse()
            .map_err(|e| format!("{label:?} failed to parse: {e}"))?;
        if parsed != spec {
            return Err(format!("{label:?} parsed to {parsed}"));
        }
        let built = spec.build(bits).map_err(|e| format!("{spec}: {e}"))?;
        if built.name() != spec.to_string() {
            return Err(format!("{spec}: built name {}", built.name()));
        }
        Ok(())
    });
}

/// Malformed labels are typed errors (wrong arity, out-of-range parameter,
/// unknown family, wrong width), never a silent fallback.
#[test]
fn malformed_labels_are_rejected_with_context() {
    // Wrong arity.
    assert!("scaleTRIM(3)".parse::<DesignSpec>().is_err());
    // Out-of-range family parameters.
    assert!("TOSAM(9,2)".parse::<DesignSpec>().is_err());
    assert!("scaleTRIM(1,4)".parse::<DesignSpec>().is_err());
    assert!("scaleTRIM(3,5)".parse::<DesignSpec>().is_err());
    assert!("MBM-0".parse::<DesignSpec>().is_err());
    // Unknown family, with near-miss suggestions in the message.
    let err = "scaletrim(3,4)".parse::<DesignSpec>().unwrap_err();
    assert!(
        err.to_string().contains("scaleTRIM(3,4)"),
        "near-miss missing from: {err}"
    );
    // Wrong width: parses, refuses to build at a mismatched width.
    let spec: DesignSpec = "Exact8".parse().unwrap();
    let e = spec.build(16).unwrap_err();
    assert!(e.to_string().contains("wrong width"), "{e}");
    let spec: DesignSpec = "AXM8-4".parse().unwrap();
    assert!(spec.build(16).is_err());
    // Width-dependent parameter violation surfaces at build time.
    let spec: DesignSpec = "DRUM(7)".parse().unwrap();
    assert!(spec.build(4).is_err(), "DRUM(7) cannot exist at 4 bits");
}

/// `enumerate` is total over the supported widths and a typed error
/// elsewhere — never an empty list that would silently skip a sweep.
#[test]
fn enumerate_supported_widths_only() {
    assert!(DesignSpec::enumerate(8).unwrap().len() > 40);
    assert!(DesignSpec::enumerate(16).unwrap().len() > 20);
    for bad in [0u32, 4, 12, 24, 32] {
        assert!(DesignSpec::enumerate(bad).is_err(), "{bad} bits must error");
    }
}
