//! DSE integration: the paper's Pareto claims recomputed end-to-end
//! (accuracy sweeps + hardware model + front extraction).

use ::scaletrim::dse::{constrained, dominance, evaluate_all, pareto_front, Dominance};
use ::scaletrim::error::SweepSpec;
use ::scaletrim::multipliers::*;

fn points() -> Vec<::scaletrim::dse::DesignPoint> {
    evaluate_all(&paper_configs_8bit(), SweepSpec::Exhaustive).expect("registry zoo evaluates")
}

#[test]
fn scaletrim_populates_the_pareto_front() {
    // Sec. IV-C: "scaleTRIM configurations consistently fall into the
    // Pareto frontier". Require at least 3 scaleTRIM members on the
    // (MRED, PDP) front.
    let pts = points();
    let front = pareto_front(&pts, |p| p.mared_energy());
    let st = front
        .iter()
        .filter(|&&i| matches!(pts[i].spec, DesignSpec::ScaleTrim { .. }))
        .count();
    assert!(
        st >= 3,
        "only {st} scaleTRIM configs on the front: {:?}",
        front.iter().map(|&i| pts[i].name.clone()).collect::<Vec<_>>()
    );
}

#[test]
fn front_is_actually_non_dominated() {
    let pts = points();
    let front = pareto_front(&pts, |p| p.mared_energy());
    for &i in &front {
        for (j, other) in pts.iter().enumerate() {
            if i == j {
                continue;
            }
            let d = dominance(
                (other.error.mred_pct, other.hw.pdp_fj),
                (pts[i].error.mred_pct, pts[i].hw.pdp_fj),
            );
            assert_ne!(
                d,
                Dominance::Dominates,
                "{} dominated by {}",
                pts[i].name,
                other.name
            );
        }
    }
}

#[test]
fn table2_window_selects_scaletrim() {
    // The paper's Table-2 window (MRED <= 4%, mid-range PDP) is won by a
    // scaleTRIM config in our measurements too.
    let pts = points();
    let sel = constrained(&pts, 4.0, (150.0, 260.0));
    assert!(!sel.is_empty());
    assert!(
        sel.iter()
            .take(3)
            .any(|p| matches!(p.spec, DesignSpec::ScaleTrim { .. })),
        "top of the window: {:?}",
        sel.iter().map(|p| p.name.clone()).take(5).collect::<Vec<_>>()
    );
}

#[test]
fn paper_reference_attached_where_published() {
    let pts = points();
    let with_ref = pts.iter().filter(|p| p.paper.is_some()).count();
    assert!(
        with_ref >= 50,
        "expected most configs to carry Table 4 reference values, got {with_ref}"
    );
}
