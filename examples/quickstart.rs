//! Quickstart: build a scaleTRIM multiplier, multiply numbers, inspect the
//! calibration, and measure its error over the full 8-bit space.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use scaletrim::error::{exhaustive_sweep, SweepSpec};
use scaletrim::hardware::estimate;
use scaletrim::multipliers::{ApproxMultiplier, DesignSpec, ScaleTrim};

fn main() -> scaletrim::Result<()> {
    // Any configuration resolves from its paper label in O(1) — the typed
    // identity plane (no zoo scan, typos get near-miss suggestions):
    let by_label = "scaleTRIM(3,4)".parse::<DesignSpec>()?.build(8)?;
    println!("resolved {} at {} bits", by_label.name(), by_label.bits());

    // scaleTRIM(h=3, M=4): 3-bit truncation, 4 compensation segments —
    // the paper's Fig. 7 configuration, constructed directly this time.
    let m = ScaleTrim::new(8, 3, 4);
    assert_eq!(m.spec(), by_label.spec());

    // The paper's worked example: 48 × 81.
    let (a, b) = (48u64, 81u64);
    println!(
        "{}: {a} × {b} ≈ {}   (exact {})",
        m.name(),
        m.mul(a, b),
        a * b
    );

    // The design-time constants the hardware would hardwire (Sec. III-A/B).
    let p = m.params();
    println!(
        "calibration: α = {:.4} (paper: 1.407), ΔEE = {} → scale (1 + 2^{})",
        p.alpha, p.delta_ee, p.delta_ee
    );
    for (i, c) in p.c.iter().enumerate() {
        println!("  compensation C[{i}] = {c:+.4}");
    }

    // Error metrics over every non-zero 8-bit operand pair (Eq. 8).
    // MARED is the abstract's name for MRED; StdARED (the relative-error
    // spread) is distinct from the Table-5 signed-ED std.
    let r = exhaustive_sweep(&m);
    println!(
        "full-space error: MARED {:.2}% (paper 3.73), StdARED {:.2}%, MED {:.1}, max {:.0}, ED-std {:.1}",
        r.mred_pct, r.stdared_pct, r.med, r.max_error, r.ed_std
    );

    // Hardware cost from the structural 45nm model (Table 4 axes).
    let hw = estimate(&m);
    println!(
        "hardware: {:.1} µm², {:.2} ns, {:.1} µW, PDP {:.1} fJ (paper: 150.8, 1.36, 113.1, 153.7)",
        hw.area_um2, hw.delay_ns, hw.power_uw, hw.pdp_fj
    );

    // The trade-off knobs: larger h / M buy accuracy with hardware.
    println!("\naccuracy-efficiency trade-off (the paper's central design space):");
    for (h, mm) in [(2u32, 0u32), (3, 4), (4, 8), (5, 8), (6, 8)] {
        let cfg = ScaleTrim::new(8, h, mm);
        let e = exhaustive_sweep(&cfg);
        let hw = estimate(&cfg);
        println!(
            "  {:<16} MRED {:>5.2}%   PDP {:>6.1} fJ",
            cfg.name(),
            e.mred_pct,
            hw.pdp_fj
        );
    }
    let _ = SweepSpec::Exhaustive; // (see `sweep` CLI for sampled 16-bit runs)
    Ok(())
}
