//! End-to-end driver (the repository's E2E validation): load a real AOT
//! artifact, serve batched requests through the PJRT runtime, cross-check
//! the pure-rust interpreter bit-for-bit, and report the accuracy-vs-PDP
//! trade-off that Fig. 15 plots — all layers (L1 Pallas kernel baked into
//! the HLO, L2 quantized model, L3 rust runtime) composing on a real small
//! workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example cnn_inference
//! ```

use scaletrim::hardware::estimate;
use scaletrim::multipliers::{ApproxMultiplier, Drum, ScaleTrim, Tosam};
use scaletrim::nn::{
    build_lut, evaluate_accuracy, evaluate_accuracy_pjrt, exact_lut, Dataset, QuantizedCnn,
    QuantizedWeights,
};
use scaletrim::runtime::{find_artifacts_dir, ArtifactSet, Engine};
use std::time::Instant;

fn main() -> scaletrim::Result<()> {
    let dir = find_artifacts_dir()?;
    let set = ArtifactSet::resolve(&dir, "lenet")?;
    let data = Dataset::load(&set.dataset)?;
    let cnn = QuantizedCnn::new(QuantizedWeights::load(&set.weights)?);

    println!("loading + compiling {} on the PJRT CPU client…", set.hlo.display());
    let engine = Engine::cpu()?;
    let model = engine.load_model(set.hlo.to_str().unwrap(), 32, data.n_classes)?;

    // 1. Bit-exactness: PJRT vs the pure-rust interpreter on one batch.
    let lut = exact_lut();
    let img_sz = data.c * data.h * data.w;
    let mut pixels = Vec::with_capacity(32 * img_sz);
    for i in 0..32 {
        pixels.extend(data.image(i).iter().map(|&p| p as i32));
    }
    let pjrt = model.run(&pixels, &[32, data.c, data.h, data.w], &lut)?;
    for i in 0..32 {
        let rust = cnn.forward(data.image(i), &lut);
        assert_eq!(&pjrt[i * 10..(i + 1) * 10], &rust[..], "logits diverged");
    }
    println!("✓ PJRT logits == pure-rust interpreter logits (32/32 images)");

    // 2. Served accuracy + throughput with the exact LUT.
    let t0 = Instant::now();
    let r = evaluate_accuracy_pjrt(&model, &data, &lut, Some(512))?;
    println!(
        "exact LUT: top1 {:.2}% over {} images  ({:.0} img/s via PJRT)",
        100.0 * r.top1,
        r.n,
        r.n as f64 / t0.elapsed().as_secs_f64()
    );

    // 3. The Fig. 15 trade-off on this model.
    println!("\naccuracy vs PDP (Fig. 15 series, lenet):");
    let configs: Vec<Box<dyn ApproxMultiplier>> = vec![
        Box::new(ScaleTrim::new(8, 3, 0)),
        Box::new(ScaleTrim::new(8, 3, 4)),
        Box::new(ScaleTrim::new(8, 4, 4)),
        Box::new(ScaleTrim::new(8, 4, 8)),
        Box::new(Drum::new(8, 3)),
        Box::new(Drum::new(8, 5)),
        Box::new(Tosam::new(8, 0, 3)),
        Box::new(Tosam::new(8, 2, 5)),
    ];
    let exact_acc = evaluate_accuracy(&cnn, &data, &lut, None);
    println!(
        "  {:<16} top1 {:>6.2}%   PDP {:>6.1} fJ",
        "Exact",
        100.0 * exact_acc.top1,
        estimate(&scaletrim::multipliers::Exact::new(8)).pdp_fj
    );
    for m in &configs {
        let r = evaluate_accuracy(&cnn, &data, &build_lut(m.as_ref()), None);
        let hw = estimate(m.as_ref());
        println!(
            "  {:<16} top1 {:>6.2}%   PDP {:>6.1} fJ",
            m.name(),
            100.0 * r.top1,
            hw.pdp_fj
        );
    }
    println!("\n(the scaleTRIM rows hold accuracy at a fraction of the exact PDP — Fig. 15's claim)");
    Ok(())
}
