//! Serving demo: the L3 coordinator routing a mixed request stream across
//! per-config lanes (exact + two scaleTRIM configs), dynamic batching under
//! a latency deadline, PJRT execution, live metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve
//! ```

use scaletrim::coordinator::{BatchPolicy, Coordinator, PjrtBackend};
use scaletrim::multipliers::{ApproxMultiplier, Exact, ScaleTrim};
use scaletrim::nn::Dataset;
use scaletrim::runtime::{find_artifacts_dir, ArtifactSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> scaletrim::Result<()> {
    let dir = find_artifacts_dir()?;
    let set = ArtifactSet::resolve(&dir, "lenet")?;
    let data = Dataset::load(&set.dataset)?;

    let backend = Arc::new(PjrtBackend::spawn(
        set.hlo.to_str().unwrap().to_string(),
        32,
        data.n_classes,
        (data.c, data.h, data.w),
    )?);

    let exact = Exact::new(8);
    let st48 = ScaleTrim::new(8, 4, 8);
    let st34 = ScaleTrim::new(8, 3, 4);
    let configs: Vec<&dyn ApproxMultiplier> = vec![&exact, &st48, &st34];
    let coord = Coordinator::new(
        backend,
        &configs,
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(4),
        },
    );
    println!("lanes: {}", coord.lane_labels().join(", "));

    // Drive 3000 requests round-robin across lanes, tracking accuracy.
    let n = 3000usize;
    let t0 = Instant::now();
    let lanes = ["Exact8", "scaleTRIM(4,8)", "scaleTRIM(3,4)"];
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let idx = i % data.n;
        pending.push((idx, coord.submit(lanes[i % 3], data.image(idx).to_vec())?.1));
    }
    let mut correct = 0usize;
    for (idx, rx) in pending {
        let p = rx.recv()?;
        assert!(p.error.is_none(), "backend error: {:?}", p.error);
        if p.class == data.labels[idx] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "served {n} requests in {dt:.2?} → {:.0} req/s, accuracy {:.2}%",
        n as f64 / dt.as_secs_f64(),
        100.0 * correct as f64 / n as f64
    );
    println!("{}", coord.metrics().summary());
    Ok(())
}
