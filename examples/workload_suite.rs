//! Application-suite tour: run every registered workload under a handful
//! of multiplier configurations and print the quality-vs-energy ledger —
//! the per-application story behind the paper's error-metric tables.
//!
//! ```sh
//! cargo run --release --example workload_suite
//! ```

use scaletrim::multipliers::{ApproxMultiplier, Drum, Mitchell, ScaleTrim, Tosam};
use scaletrim::workloads::{evaluate, registry};

fn main() -> scaletrim::Result<()> {
    let configs: Vec<Box<dyn ApproxMultiplier>> = vec![
        Box::new(ScaleTrim::new(8, 3, 4)),
        Box::new(ScaleTrim::new(8, 4, 8)),
        Box::new(ScaleTrim::new(8, 6, 8)),
        Box::new(Tosam::new(8, 1, 5)),
        Box::new(Drum::new(8, 4)),
        Box::new(Mitchell::new(8)),
    ];
    for w in registry() {
        println!("\n== {} — {}", w.name(), w.description());
        for m in &configs {
            let r = evaluate(w.as_ref(), m.as_ref())?;
            println!(
                "  {:<16} PSNR {:>6.2} dB   SSIM {:.4}   MARED {:>6.3}%   StdARED {:>6.3}%   {:>7} MACs → {:>8.3} nJ",
                r.config,
                r.quality.psnr_db,
                r.quality.ssim,
                r.quality.mared_pct,
                r.quality.stdared_pct,
                r.macs,
                r.energy_nj
            );
        }
    }
    println!(
        "\n(quality is scored against the exact-multiplier reference; energy is\n MACs × PDP of the structural hardware model — see `scaletrim repro --exp workloads`\n for the full-zoo Pareto tables)"
    );
    Ok(())
}
