//! The calibration plane end to end: calibrate the 16-bit scaleTRIM family
//! cold under every strategy, export the artifact bundle, then show a warm
//! start serving the same constants bit-for-bit from one file read.
//!
//! Run: `cargo run --release --example calib_warm`

use scaletrim::calib::{
    calibrator, default_export_entries, CalibCache, CalibStore, CalibStrategy,
};
use scaletrim::lut::calibrate;
use std::time::Instant;

fn main() -> scaletrim::Result<()> {
    // Strategy menu: same config, four ways to pay for it.
    println!("calibrating 16-bit scaleTRIM(6,8) under each strategy:");
    for strategy in CalibStrategy::ALL {
        let t0 = Instant::now();
        let p = calibrator(strategy).calibrate(16, 6, 8);
        println!(
            "  {strategy:<10} alpha={:.6}  ΔEE={}  in {:.2?}  (model cost {:.0} ops{})",
            p.alpha,
            p.delta_ee,
            t0.elapsed(),
            calibrator(strategy).cost_ops(16, 6),
            if calibrator(strategy).paper_fidelity() {
                ", paper fidelity"
            } else {
                ""
            }
        );
    }

    // Cold export of the whole 16-bit family.
    let dir = std::env::temp_dir().join(format!("scaletrim-calib-example-{}", std::process::id()));
    let store = CalibStore::at(&dir);
    let t0 = Instant::now();
    let entries = default_export_entries(16)?;
    let cold = t0.elapsed();
    let path = store.export(&entries)?;
    let bytes = std::fs::metadata(&path)?.len();
    println!(
        "\ncold-calibrated + exported {} artifacts in {cold:.2?} -> {} ({bytes} bytes)",
        entries.len(),
        path.display()
    );

    // Warm start: a fresh cache seeded from the file.
    let t0 = Instant::now();
    let loaded = store.load()?;
    let cache = CalibCache::new();
    let seeded = cache.warm(loaded.into_iter().map(|e| (e.key, e.value)));
    let warm = t0.elapsed();
    println!("warm start seeded {seeded} entries in {warm:.2?}");

    // Prove bit-for-bit identity on one config.
    let warmed = cache.scaletrim_params(16, 6, 8, CalibStrategy::Exhaustive);
    let fresh = calibrate(16, 6, 8);
    assert_eq!(warmed.alpha.to_bits(), fresh.alpha.to_bits());
    assert_eq!(warmed.c_fixed, fresh.c_fixed);
    println!(
        "scaleTRIM(6,8)@16-bit: warm constants are bit-identical to fresh calibration \
         (alpha = {:.10})",
        warmed.alpha
    );
    println!("{}", cache.stats().summary());
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
