//! Design-space exploration: evaluate the full 8-bit multiplier zoo
//! (accuracy sweep + hardware model), extract the Pareto front, and answer
//! the paper's Table-2 constraint query.
//!
//! ```sh
//! cargo run --release --example dse_pareto
//! ```

use scaletrim::dse::{constrained, evaluate_all, pareto_front};
use scaletrim::error::SweepSpec;
use scaletrim::multipliers::{paper_configs_8bit, DesignSpec};

fn main() -> scaletrim::Result<()> {
    let zoo = paper_configs_8bit();
    println!("evaluating {} configurations over the full 8-bit space…", zoo.len());
    let points = evaluate_all(&zoo, SweepSpec::Exhaustive)?;

    // Pareto front on (MRED, PDP) — Fig. 9d's star markers.
    let front = pareto_front(&points, |p| p.mared_energy());
    println!("\nPareto front (MRED% vs PDP fJ):");
    for &i in &front {
        let p = &points[i];
        println!(
            "  {:<18} MRED {:>6.2}%   PDP {:>7.1} fJ",
            p.name, p.error.mred_pct, p.hw.pdp_fj
        );
    }
    // Typed family match — no string prefix sniffing.
    let st_on_front = front
        .iter()
        .filter(|&&i| matches!(points[i].spec, DesignSpec::ScaleTrim { .. }))
        .count();
    println!(
        "\nscaleTRIM holds {st_on_front}/{} of the front — the paper's Sec. IV-C claim.",
        front.len()
    );

    // Table 2's constrained selection: MRED ≤ 4%, PDP window.
    let sel = constrained(&points, 4.0, (150.0, 260.0));
    println!("\nbest configs with MRED ≤ 4% and PDP ∈ [150, 260] fJ:");
    for p in sel.iter().take(5) {
        println!(
            "  {:<18} MRED {:>5.2}%   PDP {:>6.1} fJ   area {:>6.1} µm²",
            p.name, p.error.mred_pct, p.hw.pdp_fj, p.hw.area_um2
        );
    }
    Ok(())
}
